//! The leader (coordinator): the paper's scheduler made operational.
//!
//! Single-threaded event loop over per-worker reader threads:
//!
//! * **pump** — greedily assign ready tasks to alive workers with spare
//!   pipeline capacity (placement policy decides *which* worker);
//! * **steal** — when a worker idles and nothing is ready, revoke a queued
//!   task from a victim (steal policy decides *whom*) and reroute it;
//! * **recover** — a disconnected worker's in-flight tasks are requeued and
//!   re-executed elsewhere; purity (checked at lowering) makes this safe,
//!   which is precisely the paper's fault-tolerance argument.
//!
//! The leader owns the object store: task outputs return with `TaskDone`
//! and argument values ship inline — unless the target worker already
//! holds them, in which case a `Cached` reference saves the transfer
//! (what locality-aware placement is for).

use std::collections::{HashMap, HashSet};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cache::{ResultCache, TaskKey};
use crate::ir::task::{ArgRef, TaskId, Value};
use crate::ir::TaskProgram;
use crate::scheduler::trace::{RunResult, ScheduleTrace, TraceEvent};
use crate::scheduler::{GreedyState, PlacementPolicy, StealPolicy, WorkerId};
use crate::util::rng::Rng;
use crate::{log_debug, log_info, log_warn};

use super::message::{ArgSpec, Message};
use super::transport::{MsgReceiver, MsgSender};

/// Cluster run configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub placement: PlacementPolicy,
    pub steal: StealPolicy,
    /// Max tasks in flight (queued + running) per worker.
    pub pipeline_depth: usize,
    /// Event-loop timeout; also the liveness probe interval.
    pub heartbeat: Duration,
    /// How many worker deaths to tolerate before giving up.
    pub max_failures: usize,
    /// Ship `Cached` references for args the target worker already holds.
    pub use_cached_args: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            placement: PlacementPolicy::LeastLoaded,
            steal: StealPolicy::RandomVictim,
            pipeline_depth: 2,
            heartbeat: Duration::from_millis(200),
            max_failures: 0,
            use_cached_args: true,
        }
    }
}

enum Event {
    Msg(WorkerId, Message),
    Disconnected(WorkerId),
}

/// The leader endpoint. Owns the senders; receivers run on reader threads.
pub struct Leader {
    program: TaskProgram,
    cfg: ClusterConfig,
    senders: Vec<Box<dyn MsgSender>>,
    events: mpsc::Receiver<Event>,
    _readers: Vec<std::thread::JoinHandle<()>>,
    /// Purity-aware result cache. When set, the leader short-circuits
    /// dispatch of content-hits and deduplicates identical in-flight tasks.
    cache: Option<Arc<ResultCache>>,
}

/// Leader-side cache bookkeeping: which key each dispatched task carries,
/// which keys are currently executing somewhere, and which tasks wait for
/// an identical in-flight computation instead of running their own copy.
#[derive(Default)]
struct CacheState {
    task_keys: HashMap<TaskId, TaskKey>,
    inflight_keys: HashMap<TaskKey, TaskId>,
    waiting: HashMap<TaskKey, Vec<TaskId>>,
}

impl CacheState {
    /// Forget a task's key registration (revoke, failed send, worker
    /// death) so its re-dispatch is not deduplicated against itself.
    fn forget(&mut self, task: TaskId) {
        if let Some(k) = self.task_keys.remove(&task) {
            self.inflight_keys.remove(&k);
        }
    }
}

impl Leader {
    /// Build a leader over already-connected transports (one per worker).
    pub fn new(
        program: TaskProgram,
        links: Vec<(Box<dyn MsgSender>, Box<dyn MsgReceiver>)>,
        cfg: ClusterConfig,
    ) -> Leader {
        let (ev_tx, events) = mpsc::channel();
        let mut senders = Vec::new();
        let mut readers = Vec::new();
        for (i, (tx, mut rx)) in links.into_iter().enumerate() {
            let w = WorkerId(i as u32);
            senders.push(tx);
            let ev_tx = ev_tx.clone();
            readers.push(
                std::thread::Builder::new()
                    .name(format!("leader-rx-{w}"))
                    .spawn(move || loop {
                        match rx.recv() {
                            Ok(m) => {
                                if ev_tx.send(Event::Msg(w, m)).is_err() {
                                    return;
                                }
                            }
                            Err(_) => {
                                let _ = ev_tx.send(Event::Disconnected(w));
                                return;
                            }
                        }
                    })
                    .expect("spawn reader"),
            );
        }
        Leader {
            program,
            cfg,
            senders,
            events,
            _readers: readers,
            cache: None,
        }
    }

    /// Attach a result cache (shared across runs by the caller).
    pub fn with_cache(mut self, cache: Option<Arc<ResultCache>>) -> Leader {
        self.cache = cache;
        self
    }

    /// Drive the program to completion; returns outputs + trace.
    pub fn run(mut self) -> Result<RunResult> {
        let n_workers = self.senders.len();
        anyhow::ensure!(n_workers > 0, "cluster needs at least one worker");
        let program = self.program.clone();
        let mut state = GreedyState::new(&program, n_workers, self.cfg.placement);
        let mut values: Vec<Option<Vec<Value>>> = vec![None; program.len()];
        let mut inflight: Vec<Vec<TaskId>> = vec![Vec::new(); n_workers];
        let mut alive = vec![true; n_workers];
        let mut revoking: HashSet<TaskId> = HashSet::new();
        // task -> thief that requested the steal (assigned there on Revoked)
        let mut pending_steals: std::collections::HashMap<TaskId, WorkerId> =
            std::collections::HashMap::new();
        // dispatch timestamps: trace starts are clamped to these so the
        // reconstructed schedule respects the causal order the leader saw
        let mut assigned_at: std::collections::HashMap<TaskId, u64> =
            std::collections::HashMap::new();
        // per-worker last trace end: TaskDones arrive in execution order
        // (FIFO transport), so clamping start to this preserves the
        // worker's serial execution in the reconstructed trace
        let mut last_end = vec![0u64; n_workers];
        let mut trace = ScheduleTrace::default();
        let mut failures = 0usize;
        let mut rng = Rng::new(0x5EED);
        let mut bytes_in = 0u64; // worker->leader payload estimate
        let mut cstate = CacheState::default();
        let t0 = crate::util::now_ns();

        // Wait for Hellos (workers announce themselves) — but in-proc
        // workers start instantly; just process them as normal events.

        self.pump(&program, &mut state, &mut values, &mut inflight, &alive, &mut assigned_at, &mut trace, &mut cstate)?;

        while !state.is_done() {
            // try stealing for idle workers
            self.try_steal(&mut state, &inflight, &alive, &mut revoking, &mut pending_steals, &mut rng)?;

            let ev = match self.events.recv_timeout(self.cfg.heartbeat) {
                Ok(ev) => ev,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // liveness probe
                    for (w, s) in self.senders.iter_mut().enumerate() {
                        if alive[w] {
                            let _ = s.send(&Message::Ping);
                        }
                    }
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    bail!("all reader threads gone")
                }
            };

            match ev {
                Event::Msg(w, Message::Hello { .. }) => {
                    log_debug!("leader", "{w} connected");
                }
                Event::Msg(w, Message::TaskDone { task, outputs, compute_ns }) => {
                    bytes_in += outputs.iter().map(|v| v.size_bytes() as u64).sum::<u64>();
                    let end = crate::util::now_ns();
                    let assign_t = assigned_at.get(&task).copied().unwrap_or(0);
                    let start = end
                        .saturating_sub(compute_ns)
                        .max(assign_t)
                        .max(last_end[w.index()]);
                    let end = end.max(start);
                    last_end[w.index()] = end;
                    trace.push(TraceEvent {
                        task,
                        worker: w,
                        start_ns: start,
                        end_ns: end,
                    });
                    inflight[w.index()].retain(|t| *t != task);
                    if values[task.index()].is_none() {
                        // result cache: store the result and serve any
                        // identical tasks that were parked on this one
                        if let Some(cache) = &self.cache {
                            let spec = program.task(task);
                            if cache.cacheable(spec) {
                                let key = match cstate.task_keys.remove(&task) {
                                    Some(k) => k,
                                    // dispatched via a path that skipped
                                    // registration (steal re-assign)
                                    None => {
                                        let args = gather_arg_values(&program, &values, task)?;
                                        cache.key_for(spec, &args)
                                    }
                                };
                                cstate.inflight_keys.remove(&key);
                                cache.insert_by_key(key, &outputs);
                                for t in cstate.waiting.remove(&key).unwrap_or_default() {
                                    values[t.index()] = Some(outputs.clone());
                                    cache.note_dedup_hit();
                                    trace.record_cache_hit(t);
                                    state.complete_local(&program, t);
                                    log_debug!("leader", "dedup: served {t} from completed {task}");
                                }
                            }
                        }
                        values[task.index()] = Some(outputs);
                        state.on_done(&program, task, w);
                    } else {
                        // duplicate completion (e.g. post-revoke race) — ignore
                        log_debug!("leader", "duplicate completion of {task} from {w}");
                    }
                    self.pump(&program, &mut state, &mut values, &mut inflight, &alive, &mut assigned_at, &mut trace, &mut cstate)?;
                }
                Event::Msg(w, Message::TaskFailed { task, error }) => {
                    bail!("task {task} failed on {w}: {error}");
                }
                Event::Msg(w, Message::Revoked { task }) => {
                    revoking.remove(&task);
                    inflight[w.index()].retain(|t| *t != task);
                    cstate.forget(task);
                    state.unassign(&program, task, w);
                    log_debug!("leader", "stole {task} back from {w}");
                    // hand the stolen task straight to the thief that asked
                    // (placement would otherwise bounce it back to the busy
                    // victim under locality-aware policy)
                    let thief = pending_steals.remove(&task);
                    if let Some(thief) = thief.filter(|t| {
                        alive[t.index()] && inflight[t.index()].len() < self.cfg.pipeline_depth
                    }) {
                        if let Some(t2) = state.assign_to(&program, thief) {
                            let (args, shipped, saved) =
                                self.build_args(&program, &state, &values, t2, thief)?;
                            match self.senders[thief.index()].send(&Message::Assign {
                                task: t2,
                                op: program.task(t2).op.clone(),
                                args,
                            }) {
                                Ok(()) => {
                                    inflight[thief.index()].push(t2);
                                    assigned_at.insert(t2, crate::util::now_ns());
                                    trace.arg_bytes_shipped += shipped;
                                    trace.arg_bytes_saved += saved;
                                    log_debug!("leader", "steal-assigned {t2} -> {thief}");
                                }
                                Err(_) => state.unassign(&program, t2, thief),
                            }
                        }
                    }
                    self.pump(&program, &mut state, &mut values, &mut inflight, &alive, &mut assigned_at, &mut trace, &mut cstate)?;
                }
                Event::Msg(_, Message::RevokeDenied { task }) => {
                    revoking.remove(&task);
                    pending_steals.remove(&task);
                }
                Event::Msg(_, Message::Pong) => {}
                Event::Msg(w, Message::Bye { .. }) => {
                    log_debug!("leader", "{w} said bye");
                }
                Event::Msg(w, other) => {
                    log_warn!("leader", "unexpected {} from {w}", other.kind());
                }
                Event::Disconnected(w) => {
                    if !alive[w.index()] {
                        continue;
                    }
                    alive[w.index()] = false;
                    failures += 1;
                    let lost: Vec<TaskId> = std::mem::take(&mut inflight[w.index()]);
                    for t in &lost {
                        revoking.remove(t);
                        pending_steals.remove(t);
                        // a lost task is no longer in flight: identical
                        // tasks must not park behind it (they will be
                        // served when its re-execution completes)
                        cstate.forget(*t);
                    }
                    log_info!(
                        "leader",
                        "{w} died with {} task(s) in flight; requeueing (failure {failures}/{})",
                        lost.len(),
                        self.cfg.max_failures
                    );
                    if failures > self.cfg.max_failures {
                        bail!(
                            "worker {w} died ({} in flight) and failure budget ({}) is exhausted",
                            lost.len(),
                            self.cfg.max_failures
                        );
                    }
                    if !alive.iter().any(|a| *a) {
                        bail!("all workers dead");
                    }
                    state.requeue(&program, &lost, w);
                    state.mark_dead(w);
                    self.pump(&program, &mut state, &mut values, &mut inflight, &alive, &mut assigned_at, &mut trace, &mut cstate)?;
                }
            }
        }

        // graceful shutdown
        for (w, s) in self.senders.iter_mut().enumerate() {
            if alive[w] {
                let _ = s.send(&Message::Shutdown);
            }
        }
        // brief drain of Byes so workers exit cleanly
        while self.events.recv_timeout(Duration::from_millis(50)).is_ok() {}

        trace.wall_ns = crate::util::now_ns() - t0;
        trace.bytes_transferred =
            self.senders.iter().map(|s| s.bytes_sent()).sum::<u64>() + bytes_in;

        let outputs = program
            .outputs()
            .iter()
            .map(|o| match o {
                ArgRef::Const(v) => Ok(v.clone()),
                ArgRef::Output { task, index } => Ok(values[task.index()]
                    .as_ref()
                    .with_context(|| format!("output task {task} never completed"))?[*index]
                    .clone()),
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RunResult { outputs, trace })
    }

    /// Assign ready tasks while capacity remains.
    ///
    /// With a result cache attached, each ready task is first resolved
    /// against the cache: content hits complete at the leader without any
    /// dispatch, and a task identical to one already in flight parks until
    /// that one completes instead of executing twice.
    ///
    /// A failed send means the worker is dying: the task is requeued and
    /// the worker excluded for the rest of this pump; the authoritative
    /// death accounting happens when its `Disconnected` event arrives.
    #[allow(clippy::too_many_arguments)]
    fn pump(
        &mut self,
        program: &TaskProgram,
        state: &mut GreedyState,
        values: &mut [Option<Vec<Value>>],
        inflight: &mut [Vec<TaskId>],
        alive: &[bool],
        assigned_at: &mut std::collections::HashMap<TaskId, u64>,
        trace: &mut ScheduleTrace,
        cstate: &mut CacheState,
    ) -> Result<()> {
        let mut skip: HashSet<usize> = HashSet::new();
        loop {
            let usable = |w: usize, skip: &HashSet<usize>, inflight: &[Vec<TaskId>]| {
                alive[w] && !skip.contains(&w) && inflight[w].len() < self.cfg.pipeline_depth
            };
            let has_capacity = (0..self.senders.len()).any(|w| usable(w, &skip, inflight));
            if !has_capacity || state.n_ready() == 0 {
                return Ok(());
            }
            let Some((task, w)) = state.assign_next(program) else {
                return Ok(());
            };
            let (task, w) = if usable(w.index(), &skip, inflight) {
                (task, w)
            } else {
                // policy picked a bad target; reroute to most-idle usable worker
                state.unassign(program, task, w);
                let Some(w2) = (0..self.senders.len())
                    .filter(|i| usable(*i, &skip, inflight))
                    .min_by_key(|i| inflight[*i].len())
                else {
                    return Ok(());
                };
                let w2 = WorkerId(w2 as u32);
                // pop the (new) top of the heap and pin it to w2
                let Some(t2) = state.assign_to(program, w2) else {
                    return Ok(());
                };
                (t2, w2)
            };
            // result cache: resolve at the leader before paying dispatch
            if let Some(cache) = &self.cache {
                let spec = program.task(task);
                if cache.cacheable(spec) {
                    let arg_vals = gather_arg_values(program, values, task)?;
                    let key = cache.key_for(spec, &arg_vals);
                    // dedup first: while the provider is in flight its key
                    // cannot be in the store, and parking is neither a
                    // store hit nor a miss — it becomes a hit when served
                    if let Some(&provider) = cstate.inflight_keys.get(&key) {
                        state.abort_assign(w);
                        cstate.waiting.entry(key).or_default().push(task);
                        log_debug!(
                            "leader",
                            "dedup: {task} parked behind identical in-flight {provider}"
                        );
                        continue;
                    }
                    if let Some(outs) = cache.lookup_key(&key) {
                        state.abort_assign(w);
                        values[task.index()] = Some(outs);
                        trace.record_cache_hit(task);
                        state.complete_local(program, task);
                        log_debug!("leader", "cache hit: {task} served at the leader");
                        continue;
                    }
                    trace.cache_misses += 1;
                    cstate.task_keys.insert(task, key);
                    cstate.inflight_keys.insert(key, task);
                }
            }
            let (args, shipped, saved) = self.build_args(program, state, values, task, w)?;
            match self.senders[w.index()].send(&Message::Assign {
                task,
                op: program.task(task).op.clone(),
                args,
            }) {
                Ok(()) => {
                    inflight[w.index()].push(task);
                    assigned_at.insert(task, crate::util::now_ns());
                    trace.arg_bytes_shipped += shipped;
                    trace.arg_bytes_saved += saved;
                    log_debug!("leader", "assigned {task} -> {w}");
                }
                Err(e) => {
                    log_info!("leader", "send to {w} failed ({e:#}); requeueing {task}");
                    cstate.forget(task);
                    state.unassign(program, task, w);
                    skip.insert(w.index());
                }
            }
        }
    }

    /// Build the wire args for `task`, charging each argument either to
    /// the shipped or the saved ledger: a value the target worker already
    /// holds (per the leader's location table) goes as a `Cached`
    /// reference, anything else ships inline.
    fn build_args(
        &self,
        program: &TaskProgram,
        state: &GreedyState,
        values: &[Option<Vec<Value>>],
        task: TaskId,
        target: WorkerId,
    ) -> Result<(Vec<ArgSpec>, u64, u64)> {
        let mut shipped = 0u64;
        let mut saved = 0u64;
        let args = program
            .task(task)
            .args
            .iter()
            .map(|a| match a {
                ArgRef::Const(v) => {
                    shipped += v.size_bytes() as u64;
                    Ok(ArgSpec::Inline(v.clone()))
                }
                ArgRef::Output { task: d, index } => {
                    let outs = values[d.index()]
                        .as_ref()
                        .with_context(|| format!("{task} needs unfinished {d}"))?;
                    let bytes = outs[*index].size_bytes() as u64;
                    if self.cfg.use_cached_args && state.location(*d) == Some(target) {
                        saved += bytes;
                        Ok(ArgSpec::Cached {
                            task: *d,
                            index: *index,
                        })
                    } else {
                        shipped += bytes;
                        Ok(ArgSpec::Inline(outs[*index].clone()))
                    }
                }
            })
            .collect::<Result<Vec<_>>>()?;
        Ok((args, shipped, saved))
    }

    /// Leader-mediated work stealing: idle worker + empty ready queue →
    /// revoke a queued task from a victim.
    fn try_steal(
        &mut self,
        state: &mut GreedyState,
        inflight: &[Vec<TaskId>],
        alive: &[bool],
        revoking: &mut HashSet<TaskId>,
        pending_steals: &mut std::collections::HashMap<TaskId, WorkerId>,
        rng: &mut Rng,
    ) -> Result<()> {
        if self.cfg.steal == StealPolicy::None || state.n_ready() > 0 || state.is_done() {
            return Ok(());
        }
        if !revoking.is_empty() {
            return Ok(()); // one steal in flight at a time — no storms
        }
        let idle_exists = (0..self.senders.len()).any(|w| alive[w] && inflight[w].is_empty());
        if !idle_exists {
            return Ok(());
        }
        // victims: workers with >1 in flight (≥1 queued beyond the running one)
        let depths: Vec<usize> = inflight
            .iter()
            .enumerate()
            .map(|(w, q)| {
                if alive[w] && q.len() > 1 {
                    q.len()
                } else {
                    0
                }
            })
            .collect();
        // thief is the first idle worker
        let thief = WorkerId(
            (0..self.senders.len())
                .find(|w| alive[*w] && inflight[*w].is_empty())
                .unwrap() as u32,
        );
        let Some(victim) = self.cfg.steal.pick_victim(thief, &depths, rng) else {
            return Ok(());
        };
        // steal the most recently queued (last) task not already revoking
        let Some(&task) = inflight[victim.index()]
            .iter()
            .rev()
            .find(|t| !revoking.contains(t))
        else {
            return Ok(());
        };
        revoking.insert(task);
        pending_steals.insert(task, thief);
        log_debug!("leader", "revoking {task} from {victim} for {thief}");
        self.senders[victim.index()]
            .send(&Message::Revoke { task })
            .with_context(|| format!("revoking {task} from {victim}"))?;
        Ok(())
    }
}

/// Concrete input values of a ready task (every dependency has completed,
/// so this cannot fail on a well-formed program). Used to form the task's
/// content-addressed cache key at the leader.
fn gather_arg_values(
    program: &TaskProgram,
    values: &[Option<Vec<Value>>],
    task: TaskId,
) -> Result<Vec<Value>> {
    program
        .task(task)
        .args
        .iter()
        .map(|a| match a {
            ArgRef::Const(v) => Ok(v.clone()),
            ArgRef::Output { task: d, index } => Ok(values[d.index()]
                .as_ref()
                .with_context(|| format!("{task} is ready but {d} has no value"))?[*index]
                .clone()),
        })
        .collect()
}
