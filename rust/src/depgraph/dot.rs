//! Graphviz DOT emitter — regenerates the paper's Figure 1.
//!
//! IO nodes render as double octagons with the RealWorld chain dashed,
//! pure nodes as plain boxes; value edges are labelled with the variable
//! they carry.

use super::graph::{DepGraph, EdgeKind};

/// Render the graph as DOT.
pub fn to_dot(g: &DepGraph, title: &str) -> String {
    let mut out = String::new();
    out.push_str("digraph depgraph {\n");
    out.push_str(&format!("  label=\"{}\";\n", escape(title)));
    out.push_str("  labelloc=t;\n  rankdir=TB;\n  node [fontname=\"monospace\"];\n");
    // RealWorld source pseudo-node if any IO exists (Figure 1 draws the
    // initial world as an input).
    let has_io = g.nodes().iter().any(|n| n.io);
    if has_io {
        out.push_str("  world0 [label=\"RealWorld\", shape=plaintext];\n");
    }
    for n in g.nodes() {
        let shape = if n.io { "doubleoctagon" } else { "box" };
        let bind = n
            .binds
            .as_deref()
            .map(|b| format!("{b} = "))
            .unwrap_or_default();
        out.push_str(&format!(
            "  n{} [label=\"{}{}\", shape={}];\n",
            n.id.0,
            escape(&bind),
            escape(&n.func),
            shape
        ));
    }
    // initial world token flows to the first IO node
    if let Some(first_io) = g.nodes().iter().find(|n| {
        n.io && !g
            .predecessors(n.id)
            .any(|(e, _)| matches!(e.kind, EdgeKind::World))
    }) {
        out.push_str(&format!("  world0 -> n{} [style=dashed];\n", first_io.id.0));
    }
    for e in g.edges() {
        match &e.kind {
            EdgeKind::Value(v) => out.push_str(&format!(
                "  n{} -> n{} [label=\"{}\"];\n",
                e.src.0,
                e.dst.0,
                escape(v)
            )),
            EdgeKind::World => out.push_str(&format!(
                "  n{} -> n{} [style=dashed, label=\"RealWorld\"];\n",
                e.src.0, e.dst.0
            )),
        }
    }
    out.push_str("}\n");
    out
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::super::graph::{DepGraph, EdgeKind};
    use super::*;

    #[test]
    fn dot_contains_nodes_edges_and_world() {
        let mut g = DepGraph::new();
        let a = g.add_node("clean_files", Some("x"), true, "x <- clean_files");
        let b = g.add_node("complex_evaluation", Some("y"), false, "let y = ...");
        g.add_edge(a, b, EdgeKind::Value("x".into()));
        let dot = to_dot(&g, "fig1");
        assert!(dot.contains("doubleoctagon"));
        assert!(dot.contains("shape=box"));
        assert!(dot.contains("label=\"x\""));
        assert!(dot.contains("world0 -> n0 [style=dashed]"));
        assert!(dot.starts_with("digraph"));
        assert!(dot.ends_with("}\n"));
    }

    #[test]
    fn labels_are_escaped() {
        let mut g = DepGraph::new();
        g.add_node("f\"oo", None, false, "quote");
        let dot = to_dot(&g, "t\"itle");
        assert!(dot.contains("f\\\"oo"));
        assert!(dot.contains("t\\\"itle"));
    }
}
