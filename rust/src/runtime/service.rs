//! The runtime service: a dedicated thread owning the PJRT client and the
//! compile cache, serving execute requests over channels.
//!
//! Why an actor: `xla::PjRtClient` is `Rc`-based and `!Send`, but workers
//! are threads. Confining the client to one thread keeps the unsafe surface
//! at zero while giving every thread a cheap, cloneable [`RuntimeHandle`].
//! Requests carry host tensors; the service bridges to literals, executes,
//! and bridges back.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

#[cfg(feature = "pjrt")]
use crate::runtime::bridge::{literal_to_tensor, tensor_to_literal};
use crate::runtime::manifest::Manifest;
use crate::tensor::Tensor;
use crate::log_info;
#[cfg(feature = "pjrt")]
use crate::log_debug;

// Without `pjrt` no loop consumes the request payloads; keep the shape
// identical so the handle API does not change between builds.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
enum Request {
    /// Execute `artifact` with `args`; reply with outputs.
    Execute {
        artifact: String,
        args: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    /// Warm the compile cache.
    Precompile {
        artifact: String,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Request>,
    manifest: Arc<Manifest>,
}

impl RuntimeHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact synchronously. Inputs are validated against the
    /// manifest before they reach PJRT, so wiring bugs surface with task
    /// context instead of an XLA abort.
    pub fn execute(&self, artifact: &str, args: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let entry = self.manifest.require(artifact)?;
        if args.len() != entry.inputs.len() {
            bail!(
                "artifact {artifact}: expected {} inputs, got {}",
                entry.inputs.len(),
                args.len()
            );
        }
        for (i, (a, d)) in args.iter().zip(&entry.inputs).enumerate() {
            if a.shape() != d.shape.as_slice() || a.dtype() != d.dtype {
                bail!(
                    "artifact {artifact} input {i}: expected {}{:?}, got {}{:?}",
                    d.dtype.name(),
                    d.shape,
                    a.dtype().name(),
                    a.shape()
                );
            }
        }
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute {
                artifact: artifact.to_string(),
                args,
                reply,
            })
            .map_err(|_| anyhow!("runtime service is gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// Compile an artifact ahead of first use.
    pub fn precompile(&self, artifact: &str) -> Result<()> {
        self.manifest.require(artifact)?;
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Precompile {
                artifact: artifact.to_string(),
                reply,
            })
            .map_err(|_| anyhow!("runtime service is gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }
}

/// Owns the service thread; dropping it shuts the thread down.
pub struct RuntimeService {
    handle: RuntimeHandle,
    join: Option<JoinHandle<()>>,
    tx: mpsc::Sender<Request>,
}

impl RuntimeService {
    /// Start the service for the given artifact dir (loads the manifest).
    pub fn start(artifact_dir: PathBuf) -> Result<RuntimeService> {
        let manifest = Arc::new(Manifest::load(&artifact_dir)?);
        Self::start_with_manifest(manifest)
    }

    /// Start against the default artifact dir.
    pub fn start_default() -> Result<RuntimeService> {
        Self::start(crate::runtime::default_artifact_dir())
    }

    pub fn start_with_manifest(manifest: Arc<Manifest>) -> Result<RuntimeService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let m2 = Arc::clone(&manifest);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || service_loop(rx, m2, ready_tx))
            .context("spawning runtime thread")?;
        // Propagate client-construction failure to the caller.
        ready_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during startup"))??;
        let handle = RuntimeHandle {
            tx: tx.clone(),
            manifest,
        };
        Ok(RuntimeService {
            handle,
            join: Some(join),
            tx,
        })
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Without the `pjrt` feature there is no XLA client to build: report a
/// clear startup error (surfaced by `RuntimeService::start*`) and exit.
/// Callers fall back to the host reference executors (`--artifacts false`).
#[cfg(not(feature = "pjrt"))]
fn service_loop(
    _rx: mpsc::Receiver<Request>,
    _manifest: Arc<Manifest>,
    ready: mpsc::Sender<Result<()>>,
) {
    log_info!("runtime", "built without the `pjrt` feature; PJRT unavailable");
    let _ = ready.send(Err(anyhow!(
        "PJRT runtime unavailable: parhask was built without the `pjrt` feature \
         (pass --artifacts false to use the host reference executors)"
    )));
}

#[cfg(feature = "pjrt")]
fn service_loop(
    rx: mpsc::Receiver<Request>,
    manifest: Arc<Manifest>,
    ready: mpsc::Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("creating PJRT CPU client: {e}")));
            return;
        }
    };
    log_info!(
        "runtime",
        "PJRT up: platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Precompile { artifact, reply } => {
                let r = compile_cached(&client, &manifest, &mut cache, &artifact).map(|_| ());
                let _ = reply.send(r);
            }
            Request::Execute {
                artifact,
                args,
                reply,
            } => {
                let r = (|| -> Result<Vec<Tensor>> {
                    let t0 = crate::util::now_ns();
                    compile_cached(&client, &manifest, &mut cache, &artifact)?;
                    let exe = cache.get(&artifact).unwrap();
                    let lits: Vec<xla::Literal> = args
                        .iter()
                        .map(tensor_to_literal)
                        .collect::<Result<Vec<_>>>()?;
                    let bufs = exe
                        .execute::<xla::Literal>(&lits)
                        .with_context(|| format!("executing {artifact}"))?;
                    let result = bufs[0][0]
                        .to_literal_sync()
                        .context("fetching result literal")?;
                    // Artifacts are lowered with return_tuple=True.
                    let parts = result.to_tuple().context("untupling result")?;
                    let out = parts
                        .iter()
                        .map(literal_to_tensor)
                        .collect::<Result<Vec<_>>>()?;
                    log_debug!(
                        "runtime",
                        "{artifact}: {} -> {} in {}us",
                        args.len(),
                        out.len(),
                        (crate::util::now_ns() - t0) / 1000
                    );
                    Ok(out)
                })();
                let _ = reply.send(r);
            }
        }
    }
    log_info!("runtime", "PJRT service shutting down");
}

#[cfg(feature = "pjrt")]
fn compile_cached<'a>(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>,
    artifact: &str,
) -> Result<&'a xla::PjRtLoadedExecutable> {
    if !cache.contains_key(artifact) {
        let path = manifest.hlo_path(artifact)?;
        let t0 = crate::util::now_ns();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {artifact}"))?;
        log_info!(
            "runtime",
            "compiled {artifact} in {}ms",
            (crate::util::now_ns() - t0) / 1_000_000
        );
        cache.insert(artifact.to_string(), exe);
    }
    Ok(cache.get(artifact).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service() -> Option<RuntimeService> {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: artifacts not built");
            return None;
        }
        Some(RuntimeService::start(dir).unwrap())
    }

    #[test]
    fn matmul_artifact_matches_host_oracle() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        let a = Tensor::uniform(vec![64, 64], 1);
        let b = Tensor::uniform(vec![64, 64], 2);
        let out = h.execute("matmul_64", vec![a.clone(), b.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        let oracle = a.matmul(&b).unwrap();
        assert!(
            out[0].allclose(&oracle, 1e-4, 1e-4),
            "max diff {}",
            out[0].max_abs_diff(&oracle).unwrap()
        );
    }

    #[test]
    fn matgen_is_deterministic_in_seed() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        let g1 = h.execute("matgen_64", vec![Tensor::scalar_i32(7)]).unwrap();
        let g2 = h.execute("matgen_64", vec![Tensor::scalar_i32(7)]).unwrap();
        let g3 = h.execute("matgen_64", vec![Tensor::scalar_i32(8)]).unwrap();
        assert_eq!(g1[0], g2[0]);
        assert_ne!(g1[0], g3[0]);
        assert_eq!(g1[0].shape(), &[64, 64]);
    }

    #[test]
    fn matsum_matches_host_oracle() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        let a = Tensor::uniform(vec![64, 64], 5);
        let out = h.execute("matsum_64", vec![a.clone()]).unwrap();
        let got = out[0].scalar().unwrap();
        let want = a.sumsq().unwrap();
        assert!((got - want).abs() / want < 1e-4, "{got} vs {want}");
    }

    #[test]
    fn fused_round_equals_pipeline() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        let fused = h
            .execute(
                "matround_64",
                vec![Tensor::scalar_i32(1), Tensor::scalar_i32(2)],
            )
            .unwrap()[0]
            .scalar()
            .unwrap();
        let a = h.execute("matgen_64", vec![Tensor::scalar_i32(1)]).unwrap();
        let b = h.execute("matgen_64", vec![Tensor::scalar_i32(2)]).unwrap();
        let c = h
            .execute("matmul_64", vec![a[0].clone(), b[0].clone()])
            .unwrap();
        let s = h.execute("matsum_64", vec![c[0].clone()]).unwrap()[0]
            .scalar()
            .unwrap();
        assert!((fused - s).abs() / s.abs() < 1e-4, "{fused} vs {s}");
    }

    #[test]
    fn input_validation_rejects_bad_shapes() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        let bad = Tensor::uniform(vec![32, 32], 0);
        let err = h
            .execute("matmul_64", vec![bad.clone(), bad])
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected f32[64, 64]"), "{err}");
        assert!(h.execute("matmul_64", vec![]).is_err());
        assert!(h.execute("no_such_artifact", vec![]).is_err());
    }

    #[test]
    fn handle_is_send_and_usable_from_threads() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        let threads: Vec<_> = (0..4)
            .map(|i| {
                let h = h.clone();
                std::thread::spawn(move || {
                    let g = h
                        .execute("matgen_64", vec![Tensor::scalar_i32(i)])
                        .unwrap();
                    g[0].sumsq().unwrap()
                })
            })
            .collect();
        let sums: Vec<f32> = threads.into_iter().map(|t| t.join().unwrap()).collect();
        assert!(sums.iter().all(|s| *s > 0.0));
    }
}
