//! Worker node: receive tasks, execute through an [`Executor`], reply.
//!
//! Holds an output cache so the leader can send `ArgSpec::Cached`
//! references instead of re-shipping tensors (what makes the
//! locality-aware placement policy worth having). Supports fault
//! injection — dying abruptly after N tasks — used by the fault-tolerance
//! tests and the recovery ablation.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::ir::task::{TaskId, Value};
use crate::scheduler::WorkerId;
use crate::tasks::Executor;
use crate::{log_debug, log_info};

use super::message::{ArgSpec, Message};
use super::transport::{MsgReceiver, MsgSender};

/// Fault injection plan for a worker.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    /// Die (drop the connection without a `Bye`) after completing this
    /// many tasks.
    pub die_after_tasks: Option<usize>,
}

/// A worker endpoint. Generic over transport halves.
pub struct Worker<S: MsgSender, R: MsgReceiver> {
    pub id: WorkerId,
    tx: S,
    rx: R,
    executor: Arc<dyn Executor>,
    /// task -> outputs we produced (leader may reference these as Cached).
    cache: HashMap<TaskId, Vec<Value>>,
    /// tasks assigned but not yet started (revocable).
    queue: VecDeque<(TaskId, crate::ir::task::OpKind, Vec<ArgSpec>)>,
    fault: FaultPlan,
    completed: usize,
}

impl<S: MsgSender, R: MsgReceiver> Worker<S, R> {
    pub fn new(id: WorkerId, tx: S, rx: R, executor: Arc<dyn Executor>) -> Self {
        Worker {
            id,
            tx,
            rx,
            executor,
            cache: HashMap::new(),
            queue: VecDeque::new(),
            fault: FaultPlan::default(),
            completed: 0,
        }
    }

    pub fn with_fault(mut self, fault: FaultPlan) -> Self {
        self.fault = fault;
        self
    }

    /// Main loop: runs until `Shutdown` (graceful) or injected death.
    pub fn run(mut self) -> Result<()> {
        self.tx
            .send(&Message::Hello { worker: self.id })
            .context("worker hello")?;
        log_info!("worker", "{} up", self.id);
        loop {
            // Drain queued work before blocking on the next message.
            if let Some((task, op, args)) = self.queue.pop_front() {
                self.execute_task(task, op, args)?;
                if let Some(k) = self.fault.die_after_tasks {
                    if self.completed >= k {
                        log_info!("worker", "{} injected death after {k} tasks", self.id);
                        return Ok(()); // drop connection without Bye
                    }
                }
                // Between tasks, ingest pending control messages (revokes,
                // new assignments) without blocking. Zero-duration drain:
                // a 1ms poll here was the dominant per-task overhead
                // (≈555µs/task → ≈40µs/task, see EXPERIMENTS.md §Perf).
                while let Ok(Some(m)) = self.rx.recv_timeout(std::time::Duration::ZERO) {
                    if !self.handle(m)? {
                        return Ok(());
                    }
                }
                continue;
            }
            match self.rx.recv() {
                Ok(msg) => {
                    if !self.handle(msg)? {
                        return Ok(());
                    }
                }
                Err(e) => {
                    log_info!("worker", "{} leader gone: {e:#}", self.id);
                    return Ok(());
                }
            }
        }
    }

    /// Returns false to stop.
    fn handle(&mut self, msg: Message) -> Result<bool> {
        match msg {
            Message::Assign { task, op, args } => {
                self.queue.push_back((task, op, args));
            }
            Message::Revoke { task } => {
                // Only queued (not started) tasks can be returned.
                if let Some(pos) = self.queue.iter().position(|(t, _, _)| *t == task) {
                    self.queue.remove(pos);
                    self.tx.send(&Message::Revoked { task })?;
                } else {
                    self.tx.send(&Message::RevokeDenied { task })?;
                }
            }
            Message::Ping => self.tx.send(&Message::Pong)?,
            Message::Shutdown => {
                self.tx.send(&Message::Bye { worker: self.id }).ok();
                log_info!("worker", "{} shutting down", self.id);
                return Ok(false);
            }
            other => {
                log_debug!("worker", "{} ignoring {}", self.id, other.kind());
            }
        }
        Ok(true)
    }

    fn execute_task(
        &mut self,
        task: TaskId,
        op: crate::ir::task::OpKind,
        args: Vec<ArgSpec>,
    ) -> Result<()> {
        let resolved: Result<Vec<Value>> = args
            .into_iter()
            .map(|a| match a {
                ArgSpec::Inline(v) => Ok(v),
                ArgSpec::Cached { task, index } => self
                    .cache
                    .get(&task)
                    .and_then(|outs| outs.get(index))
                    .cloned()
                    .with_context(|| format!("{} missing cached {task}[{index}]", self.id)),
            })
            .collect();
        let t0 = crate::util::now_ns();
        let result = resolved.and_then(|vals| self.executor.execute(&op, &vals));
        let compute_ns = crate::util::now_ns() - t0;
        match result {
            Ok(outputs) => {
                self.cache.insert(task, outputs.clone());
                self.completed += 1;
                self.tx.send(&Message::TaskDone {
                    task,
                    outputs,
                    compute_ns,
                })?;
            }
            Err(e) => {
                self.tx.send(&Message::TaskFailed {
                    task,
                    error: format!("{e:#}"),
                })?;
            }
        }
        Ok(())
    }
}
