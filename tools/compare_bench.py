#!/usr/bin/env python3
"""Diff the two most recent BENCH_*.json snapshots and fail on regression.

Usage:
    python3 tools/compare_bench.py                 # discover in repo root
    python3 tools/compare_bench.py OLD.json NEW.json
    python3 tools/compare_bench.py --threshold 0.15
    python3 tools/compare_bench.py --self-test     # prove the comparator works

Every numeric leaf in the snapshot schema (see README "Bench snapshots")
is lower-is-better: nanosecond timings, bytes moved, task counts. A
metric in the newer snapshot that exceeds the older one by more than
THRESHOLD (default 10%) is a regression and the script exits non-zero,
listing every offender. A zero (or sub-floor) baseline does not grant a
free pass: a metric that climbs from ~0 to meaningfully above the noise
floor fails too. A metric that disappears from the newer snapshot is
also a failure — silently dropping a gauge is how regressions hide.
Sweep arrays are matched row-by-row on their identity keys ("size",
"k") so reordering or adding sweep points never produces a false diff;
sweep rows present on only one side are reported as informational.

With fewer than two snapshots on disk there is nothing to compare: the
script says so loudly and exits 0, so CI stays green on the first PR
that records a snapshot.

Stdlib only — no pip installs.
"""

import argparse
import json
import os
import re
import sys
import tempfile

IDENTITY_KEYS = ("size", "k")
# counters that describe the workload, not the performance of the code
# (cross_tenant_hits is higher-is-better, so it cannot use the
# lower-is-better regression rule either)
INFORMATIONAL = {
    "tasks", "codec_msg_bytes", "schema", "snapshot",
    "sessions", "cross_tenant_hits",
}
# below this many ns, timer jitter dwarfs any real effect
ABS_FLOOR = 1.0


def natural_key(name):
    """BENCH_pr7.json < BENCH_pr10.json (lexicographic sort gets this wrong)."""
    return [int(t) if t.isdigit() else t for t in re.split(r"(\d+)", name)]


def discover(root):
    names = [
        n
        for n in os.listdir(root)
        if n.startswith("BENCH_") and n.endswith(".json")
    ]
    names.sort(key=natural_key)
    return [os.path.join(root, n) for n in names]


def row_identity(row):
    return tuple((k, row[k]) for k in IDENTITY_KEYS if k in row)


def compare(old, new, path, threshold, regressions, notes):
    """Walk both trees in lockstep, recording >threshold numeric growth."""
    if isinstance(old, dict) and isinstance(new, dict):
        for key in sorted(set(old) | set(new)):
            if key in INFORMATIONAL:
                continue
            here = f"{path}.{key}" if path else key
            if key not in old:
                notes.append(f"{here}: new metric (no baseline)")
            elif key not in new:
                regressions.append(
                    f"{here}: metric dropped from snapshot "
                    f"(baseline was {old[key]!r})"
                )
            else:
                compare(old[key], new[key], here, threshold, regressions, notes)
    elif isinstance(old, list) and isinstance(new, list):
        if all(isinstance(r, dict) for r in old + new):
            old_rows = {row_identity(r): r for r in old}
            new_rows = {row_identity(r): r for r in new}
            for ident in old_rows:
                label = ",".join(f"{k}={v}" for k, v in ident) or "row"
                here = f"{path}[{label}]"
                if ident in new_rows:
                    compare(
                        old_rows[ident], new_rows[ident], here, threshold,
                        regressions, notes,
                    )
                else:
                    notes.append(f"{here}: sweep point dropped from snapshot")
            for ident in new_rows:
                if ident not in old_rows:
                    label = ",".join(f"{k}={v}" for k, v in ident) or "row"
                    notes.append(f"{path}[{label}]: new sweep point (no baseline)")
        else:
            for i, (o, n) in enumerate(zip(old, new)):
                compare(o, n, f"{path}[{i}]", threshold, regressions, notes)
    elif isinstance(old, (int, float)) and isinstance(new, (int, float)):
        # Sub-floor baselines are pure timer jitter, so measure growth
        # against max(old, ABS_FLOOR): a 0.4ns -> 0.9ns wiggle passes,
        # but 0.0 -> 50.0 is a real regression, not a free pass (and the
        # old `old >= ABS_FLOOR` guard also dodged dividing by zero by
        # never flagging zero baselines at all).
        baseline = max(float(old), ABS_FLOOR)
        if new > baseline * (1.0 + threshold):
            if old > 0:
                delta = f"+{(new / old - 1.0) * 100.0:.1f}%"
            else:
                delta = f"+{new - old:.1f} from zero baseline"
            regressions.append(
                f"{path}: {old:.1f} -> {new:.1f}  ({delta}, limit "
                f"+{threshold * 100:.0f}%)"
            )
    # strings and mixed types: nothing to compare


def run_compare(old_path, new_path, threshold):
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    regressions, notes = [], []
    compare(old, new, "", threshold, regressions, notes)
    print(
        f"comparing {os.path.basename(old_path)} "
        f"({old.get('snapshot', '?')}) -> {os.path.basename(new_path)} "
        f"({new.get('snapshot', '?')})"
    )
    for n in notes:
        print(f"  note: {n}")
    if regressions:
        print(f"\nPERF REGRESSION: {len(regressions)} metric(s) slowed by more "
              f"than {threshold * 100:.0f}%:", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    print(f"ok: no metric regressed by more than {threshold * 100:.0f}%")
    return 0


def self_test(threshold):
    """Synthetic fixtures proving regressions are caught and noise is not."""
    base = {
        "schema": "parhask-bench-snapshot/1",
        "snapshot": "prA",
        "substrate": {"codec_encode_ns": 100.0, "deque_steal_ns": 0.4},
        "sim_partition_sweep": [
            {"size": 256, "k": 1, "tasks": 9, "makespan_ns": 1000.0},
            {"size": 256, "k": 4, "tasks": 21, "makespan_ns": 400.0},
        ],
    }
    # 9% slower everywhere: must pass
    ok = json.loads(json.dumps(base))
    ok["snapshot"] = "prB"
    ok["substrate"]["codec_encode_ns"] = 109.0
    ok["sim_partition_sweep"][1]["makespan_ns"] = 436.0
    # one sweep point 50% slower: must fail, and the sub-floor timer
    # (0.4ns -> 0.9ns, +125%) must NOT be what fails it
    bad = json.loads(json.dumps(base))
    bad["snapshot"] = "prC"
    bad["sim_partition_sweep"][1]["makespan_ns"] = 600.0
    bad["substrate"]["deque_steal_ns"] = 0.9
    # identical but reordered sweep rows: must pass (identity matching)
    reordered = json.loads(json.dumps(base))
    reordered["snapshot"] = "prD"
    reordered["sim_partition_sweep"].reverse()
    # a metric whose baseline is exactly zero, then jumps well past the
    # noise floor: must fail (the old guard skipped zero baselines)
    zbase = json.loads(json.dumps(base))
    zbase["substrate"]["admission_wait_ns"] = 0.0
    zjump = json.loads(json.dumps(zbase))
    zjump["snapshot"] = "prE"
    zjump["substrate"]["admission_wait_ns"] = 50.0
    # a metric silently vanishing from the newer snapshot: must fail
    dropped = json.loads(json.dumps(base))
    dropped["snapshot"] = "prF"
    del dropped["substrate"]["codec_encode_ns"]

    with tempfile.TemporaryDirectory() as d:
        paths = {}
        docs = [
            ("a", base), ("b", ok), ("c", bad), ("d", reordered),
            ("z0", zbase), ("z1", zjump), ("e", dropped),
        ]
        for name, doc in docs:
            paths[name] = os.path.join(d, f"BENCH_{name}.json")
            with open(paths[name], "w") as f:
                json.dump(doc, f)
        cases = [
            (paths["a"], paths["b"], 0, "within-threshold growth passes"),
            (paths["a"], paths["c"], 1, ">threshold regression fails"),
            (paths["a"], paths["d"], 0, "row reordering is not a regression"),
            (paths["c"], paths["a"], 0, "improvements always pass"),
            (paths["z0"], paths["z1"], 1, "zero-baseline jump is a regression"),
            (paths["z1"], paths["z0"], 0, "returning to zero is fine"),
            (paths["a"], paths["e"], 1, "dropped metric is a failure"),
            (paths["e"], paths["a"], 0, "new metric is only a note"),
        ]
        failed = False
        for old_p, new_p, want, what in cases:
            got = run_compare(old_p, new_p, threshold)
            status = "PASS" if got == want else "FAIL"
            if got != want:
                failed = True
            print(f"self-test [{status}]: {what} (exit {got}, want {want})\n")
    if failed:
        print("self-test FAILED", file=sys.stderr)
        return 1
    print("self-test passed: comparator detects regressions and only regressions")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshots", nargs="*", help="explicit OLD.json NEW.json")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional regression limit (default 0.10 = 10%%)")
    ap.add_argument("--root", default=".",
                    help="directory to discover BENCH_*.json in")
    ap.add_argument("--self-test", action="store_true",
                    help="run the comparator against synthetic fixtures")
    args = ap.parse_args()

    if args.self_test:
        return self_test(args.threshold)
    if len(args.snapshots) == 2:
        old_path, new_path = args.snapshots
    elif not args.snapshots:
        found = discover(args.root)
        if len(found) < 2:
            have = ", ".join(os.path.basename(p) for p in found) or "none"
            print(
                "compare_bench: NOTHING TO COMPARE — need two BENCH_*.json "
                f"snapshots, found {len(found)} ({have}). Record one per PR "
                "with `cargo bench --bench bench_snapshot`."
            )
            return 0
        old_path, new_path = found[-2], found[-1]
    else:
        ap.error("pass exactly two snapshot paths, or none to auto-discover")
    return run_compare(old_path, new_path, args.threshold)


if __name__ == "__main__":
    sys.exit(main())
