//! Micro-benchmarks of the substrates on the hot path — the numbers the
//! §Perf iteration log in EXPERIMENTS.md tracks:
//!
//! * codec encode/decode throughput (tensor-bearing messages);
//! * work-stealing deque push/pop and steal rates;
//! * JSON manifest parse;
//! * PJRT artifact execute latency (the real task floor);
//! * leader round-trip overhead per task (empty-ish tasks through the
//!   in-proc cluster vs raw executor calls).
//!
//! ```sh
//! cargo bench --bench micro_substrate
//! ```

use std::sync::Arc;

use parhask::cluster::codec;
use parhask::cluster::message::Message;
use parhask::ir::task::{CostEst, OpKind, TaskId, Value};
use parhask::ir::ProgramBuilder;
use parhask::metrics::Table;
use parhask::scheduler::deque::WorkDeque;
use parhask::tensor::Tensor;

fn bench<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    // one warmup batch, then timed
    f();
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

fn main() -> anyhow::Result<()> {
    let mut t = Table::new("substrate micro-benchmarks", &["benchmark", "per-op", "throughput"]);

    // --- codec --------------------------------------------------------------
    let msg = Message::TaskDone {
        task: TaskId(7),
        outputs: vec![Value::tensor(Tensor::uniform(vec![256, 256], 1))],
        compute_ns: 12345,
    };
    let encoded = codec::encode(&msg);
    let sz = encoded.len() as f64;
    let enc_ns = bench(200, || {
        std::hint::black_box(codec::encode(&msg));
    });
    t.row(vec![
        "codec encode 256x256 tensor msg".into(),
        format!("{:.1} us", enc_ns / 1e3),
        format!("{:.2} GB/s", sz / enc_ns),
    ]);
    let dec_ns = bench(200, || {
        std::hint::black_box(codec::decode(&encoded).unwrap());
    });
    t.row(vec![
        "codec decode 256x256 tensor msg".into(),
        format!("{:.1} us", dec_ns / 1e3),
        format!("{:.2} GB/s", sz / dec_ns),
    ]);

    // --- deque ---------------------------------------------------------------
    let d = WorkDeque::<u32>::with_capacity(1024);
    let pp_ns = bench(1000, || {
        for i in 0..64u32 {
            d.push(i);
        }
        while d.pop().is_some() {}
    }) / 128.0;
    t.row(vec![
        "deque push+pop (owner)".into(),
        format!("{:.1} ns", pp_ns),
        format!("{:.0} Mops/s", 1e3 / pp_ns),
    ]);
    for i in 0..512u32 {
        d.push(i);
    }
    let steal_ns = bench(512, || {
        let _ = std::hint::black_box(d.steal());
    });
    t.row(vec![
        "deque steal (uncontended)".into(),
        format!("{:.1} ns", steal_ns),
        format!("{:.0} Mops/s", 1e3 / steal_ns),
    ]);

    // --- json manifest --------------------------------------------------------
    let dir = parhask::runtime::default_artifact_dir();
    if let Ok(text) = std::fs::read_to_string(dir.join("manifest.json")) {
        let parse_ns = bench(100, || {
            std::hint::black_box(parhask::util::json::Json::parse(&text).unwrap());
        });
        t.row(vec![
            format!("manifest.json parse ({} B)", text.len()),
            format!("{:.1} us", parse_ns / 1e3),
            format!("{:.0} MB/s", text.len() as f64 / parse_ns * 1e3),
        ]);
    }

    // --- PJRT execute latency ---------------------------------------------------
    match parhask::runtime::RuntimeService::start_default() {
        Ok(svc) => {
            let h = svc.handle();
            for name in ["matmul_64", "matmul_256", "matsum_256", "matgen_256"] {
                h.precompile(name)?;
                let entry = h.manifest().require(name)?.clone();
                let args: Vec<Tensor> = entry
                    .inputs
                    .iter()
                    .map(|d| match d.dtype {
                        parhask::tensor::DType::F32 => Tensor::uniform(d.shape.clone(), 3),
                        parhask::tensor::DType::I32 => {
                            let n: usize = d.shape.iter().product();
                            Tensor::i32(d.shape.clone(), vec![1; n]).unwrap()
                        }
                    })
                    .collect();
                let ns = bench(20, || {
                    std::hint::black_box(h.execute(name, args.clone()).unwrap());
                });
                let gflops = entry.flops as f64 / ns;
                t.row(vec![
                    format!("PJRT execute {name}"),
                    format!("{:.1} us", ns / 1e3),
                    format!("{gflops:.2} GFLOP/s"),
                ]);
            }
        }
        Err(e) => {
            t.row(vec![format!("PJRT skipped: {e}"), "-".into(), "-".into()]);
        }
    }

    // --- leader overhead per task -------------------------------------------------
    {
        use parhask::cluster::{run_cluster_inproc, ClusterConfig};
        use parhask::tasks::SyntheticExecutor;
        let n_tasks = 200usize;
        let mut b = ProgramBuilder::new();
        for i in 0..n_tasks {
            b.push(
                OpKind::Synthetic { compute_us: 0 },
                vec![],
                1,
                CostEst { flops: 1, bytes_in: 0, bytes_out: 1 },
                format!("t{i}"),
            );
        }
        let p = b.build().unwrap();
        let mut best = f64::MAX;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            let r = run_cluster_inproc(
                &p,
                Arc::new(SyntheticExecutor),
                2,
                ClusterConfig::default(),
                None,
            )?;
            let dt = t0.elapsed().as_nanos() as f64;
            assert_eq!(r.trace.events.len(), n_tasks);
            best = best.min(dt / n_tasks as f64);
        }
        t.row(vec![
            "cluster round-trip / empty task".into(),
            format!("{:.1} us", best / 1e3),
            format!("{:.0} tasks/s", 1e9 / best),
        ]);
    }

    println!("{}", t.render());
    Ok(())
}
