//! serve_storm — a submission storm against the multi-tenant serving
//! plane: 100 concurrent sessions of mixed size (84 tiny + 15 medium
//! matrix programs drawn from small pools, so tenants overlap, plus one
//! huge synthetic program) share 4 workers and one result cache.
//!
//! Checks the serving plane's acceptance properties at bench scale and
//! prints the latency report:
//!
//! * zero lost or incorrect results — every session's outputs are
//!   compared against a solo single-thread run of its program;
//! * cross-tenant cache hits — duplicate tenants pay for the shared pure
//!   work once;
//! * fairness — small-program p99 stays below the huge tenant's
//!   end-to-end time (the quantum preempts the big session).
//!
//! ```sh
//! cargo bench --bench serve_storm
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use parhask::baselines::run_single;
use parhask::cache::{CacheConfig, ResultCache};
use parhask::ir::task::{ArgRef, CostEst, OpKind, TaskId, Value};
use parhask::ir::{ProgramBuilder, TaskProgram};
use parhask::metrics::{Histogram, Table};
use parhask::serve::{ServeConfig, ServePlane};
use parhask::tasks::HostExecutor;
use parhask::workload::matrix_program;

const N_TINY: usize = 84;
const N_MEDIUM: usize = 15;

/// Wide layered pure spin program: the storm's one huge tenant
/// (width × layers × us of serial compute, width-way parallel).
fn huge_program(width: usize, layers: usize, us: u64) -> TaskProgram {
    let mut b = ProgramBuilder::new();
    let mut prev: Vec<TaskId> = Vec::new();
    for l in 0..layers {
        let mut cur = Vec::new();
        for i in 0..width {
            let args = if l == 0 {
                vec![ArgRef::const_i32((l * width + i) as i32)]
            } else {
                vec![ArgRef::out(prev[i], 0)]
            };
            cur.push(b.push(
                OpKind::Synthetic { compute_us: us },
                args,
                1,
                CostEst::ZERO,
                format!("huge{l}_{i}"),
            ));
        }
        prev = cur;
    }
    b.mark_output(ArgRef::out(prev[0], 0));
    b.build().expect("huge program is well-formed")
}

fn main() -> anyhow::Result<()> {
    // tenant pools: 3 tiny shapes and 3 medium shapes, so the storm has
    // heavy cross-tenant overlap without being 100 copies of one program
    let tiny_pool: Vec<TaskProgram> =
        (1..=3).map(|t| matrix_program(t, 16, false, None)).collect();
    let medium_pool: Vec<TaskProgram> =
        (4..=6).map(|t| matrix_program(t, 48, false, None)).collect();
    let huge = huge_program(32, 4, 800);

    let solo = |p: &TaskProgram| -> Vec<Value> {
        run_single(p, &HostExecutor).expect("solo run").outputs
    };
    let tiny_want: Vec<Vec<Value>> = tiny_pool.iter().map(solo).collect();
    let medium_want: Vec<Vec<Value>> = medium_pool.iter().map(solo).collect();
    let huge_want = solo(&huge);

    let mut cc = CacheConfig::default();
    cc.enabled = true;
    cc.namespace = "host".into();
    let plane = ServePlane::start_inproc(
        Arc::new(HostExecutor),
        ServeConfig {
            workers: 4,
            quantum: Duration::from_millis(5),
            max_sessions: 128,
            ..ServeConfig::default()
        },
        Some(ResultCache::new(cc)),
    )?;

    let t0 = Instant::now();
    let huge_ticket = plane.submit(huge.clone())?;
    let tiny_tickets: Vec<_> = (0..N_TINY)
        .map(|i| Ok((i % tiny_pool.len(), plane.submit(tiny_pool[i % tiny_pool.len()].clone())?)))
        .collect::<anyhow::Result<_>>()?;
    let medium_tickets: Vec<_> = (0..N_MEDIUM)
        .map(|i| {
            Ok((i % medium_pool.len(), plane.submit(medium_pool[i % medium_pool.len()].clone())?))
        })
        .collect::<anyhow::Result<_>>()?;

    let mut small_e2e = Histogram::new();
    let mut medium_e2e = Histogram::new();
    let mut incorrect = 0usize;
    for (k, t) in tiny_tickets {
        let o = t.wait()?;
        if o.outputs != tiny_want[k] {
            eprintln!("tiny session {} (pool {k}): WRONG OUTPUTS", o.id);
            incorrect += 1;
        }
        small_e2e.record_ns(o.metrics.e2e_ns);
    }
    for (k, t) in medium_tickets {
        let o = t.wait()?;
        if o.outputs != medium_want[k] {
            eprintln!("medium session {} (pool {k}): WRONG OUTPUTS", o.id);
            incorrect += 1;
        }
        medium_e2e.record_ns(o.metrics.e2e_ns);
    }
    let huge_outcome = huge_ticket.wait()?;
    if huge_outcome.outputs != huge_want {
        eprintln!("huge session {}: WRONG OUTPUTS", huge_outcome.id);
        incorrect += 1;
    }
    let wall = t0.elapsed();
    let mut stats = plane.shutdown()?;

    let sessions = (1 + N_TINY + N_MEDIUM) as u64;
    assert_eq!(incorrect, 0, "{incorrect} session(s) returned wrong results");
    assert_eq!(stats.completed, sessions, "lost sessions: {stats:?}");
    assert_eq!(stats.failed, 0);
    assert!(
        stats.cross_tenant_hits > 0,
        "overlapping tenants produced no cross-tenant cache hits"
    );
    let small_p99 = small_e2e.p99();
    let huge_e2e = huge_outcome.metrics.e2e_ns as f64;
    assert!(
        small_p99 < huge_e2e,
        "small p99 {:.1} ms not bounded below huge e2e {:.1} ms — starved",
        small_p99 / 1e6,
        huge_e2e / 1e6
    );

    let mut t = Table::new(
        "serve_storm",
        &["class", "sessions", "p50_ms", "p95_ms", "p99_ms", "max_ms"],
    );
    let mut row = |name: &str, h: &mut Histogram| {
        t.row(vec![
            name.to_string(),
            h.count().to_string(),
            format!("{:.3}", h.p50() / 1e6),
            format!("{:.3}", h.p95() / 1e6),
            format!("{:.3}", h.p99() / 1e6),
            format!("{:.3}", h.max() / 1e6),
        ]);
    };
    row("tiny", &mut small_e2e);
    row("medium", &mut medium_e2e);
    let mut huge_h = Histogram::new();
    huge_h.record_ns(huge_outcome.metrics.e2e_ns);
    row("huge", &mut huge_h);
    println!("{}", t.render());
    println!("{}", stats.table().render());
    println!(
        "storm: {} sessions in {:.1} ms ({:.0} sessions/s), huge preempted {} time(s)",
        sessions,
        wall.as_secs_f64() * 1e3,
        sessions as f64 / wall.as_secs_f64(),
        huge_outcome.metrics.quantum_expiries,
    );
    Ok(())
}
