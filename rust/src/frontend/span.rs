//! Source positions and spans for diagnostics.

/// Byte offset range in the source, plus 1-based line/col of the start.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Span {
    pub start: usize,
    pub end: usize,
    pub line: u32,
    pub col: u32,
}

impl Span {
    pub const DUMMY: Span = Span {
        start: 0,
        end: 0,
        line: 0,
        col: 0,
    };

    pub fn new(start: usize, end: usize, line: u32, col: u32) -> Span {
        Span {
            start,
            end,
            line,
            col,
        }
    }

    /// Smallest span covering both.
    pub fn to(self, other: Span) -> Span {
        Span {
            start: self.start.min(other.start),
            end: self.end.max(other.end),
            line: self.line.min(other.line),
            col: if other.line < self.line { other.col } else { self.col },
        }
    }
}

impl std::fmt::Display for Span {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_covers_both() {
        let a = Span::new(3, 7, 1, 4);
        let b = Span::new(10, 14, 2, 1);
        let j = a.to(b);
        assert_eq!((j.start, j.end), (3, 14));
        assert_eq!(j.line, 1);
    }
}
