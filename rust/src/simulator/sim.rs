//! The discrete-event simulation proper.
//!
//! Drives the *same* [`GreedyState`] the real leader uses, but over
//! virtual time:
//!
//! * assignment: leader pays `dispatch_ns`, then the task's non-local
//!   argument bytes travel at the network rate; the task arrives in the
//!   worker's FIFO queue;
//! * compute: workers are serial servers — `start = max(free_at, arrive)`,
//!   `end = start + cost(task)`;
//! * completion: output bytes travel back; only then does the leader see
//!   the completion and assign successors (exactly the real protocol's
//!   round trip).
//!
//! `transfer_free: true` removes dispatch + network costs — that is the
//! SMP/shared-memory model (and with one worker, the single-thread model),
//! so all three Figure-2 engines come out of one simulator.

use std::collections::{BinaryHeap, HashSet};

use anyhow::Result;

use crate::ir::task::TaskId;
use crate::ir::TaskProgram;
use crate::scheduler::trace::{ScheduleTrace, TraceEvent};
use crate::scheduler::{GreedyState, PlacementPolicy, WorkerId};
use crate::util::rng::Rng;

use super::costmodel::CostModel;

/// Simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub n_workers: usize,
    pub placement: PlacementPolicy,
    pub pipeline_depth: usize,
    /// Shared-memory mode: no dispatch/network costs.
    pub transfer_free: bool,
}

impl SimConfig {
    pub fn cluster(n_workers: usize) -> SimConfig {
        SimConfig {
            n_workers,
            placement: PlacementPolicy::LeastLoaded,
            pipeline_depth: 2,
            transfer_free: false,
        }
    }

    pub fn smp(n_workers: usize) -> SimConfig {
        SimConfig {
            n_workers,
            placement: PlacementPolicy::LeastLoaded,
            pipeline_depth: 2,
            transfer_free: true,
        }
    }

    pub fn single() -> SimConfig {
        SimConfig::smp(1)
    }
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub makespan_ns: u64,
    pub trace: ScheduleTrace,
    pub bytes_transferred: u64,
    pub utilization: f64,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Ev {
    /// Assignment lands in the worker queue.
    Arrive(WorkerId, TaskId),
    /// Worker finished computing; output starts its trip back.
    Computed(WorkerId, TaskId),
    /// Leader has the result.
    LeaderSees(WorkerId, TaskId),
    /// Leader served the task from the modeled warm result cache — no
    /// dispatch, no compute, no transfer; completes after `cache_serve_ns`.
    CacheServed(TaskId),
}

#[derive(PartialEq, Eq)]
struct QEv {
    t: u64,
    seq: u64, // FIFO tie-break for determinism
    ev: Ev,
}

impl Ord for QEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap via reverse
        (other.t, other.seq).cmp(&(self.t, self.seq))
    }
}

impl PartialOrd for QEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Run the simulation; deterministic for a given (program, config, model).
pub fn simulate(program: &TaskProgram, cm: &CostModel, cfg: &SimConfig) -> Result<SimResult> {
    anyhow::ensure!(cfg.n_workers >= 1, "need at least one worker");
    let mut state = GreedyState::new(program, cfg.n_workers, cfg.placement);
    let mut heap: BinaryHeap<QEv> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut now = 0u64;
    let mut free_at = vec![0u64; cfg.n_workers];
    let mut inflight = vec![0usize; cfg.n_workers];
    let mut trace = ScheduleTrace::default();
    let mut bytes = 0u64;

    // Modeled warm cache: each pure task is independently a hit with
    // probability `cache_hit_rate` (fixed seed — the sweep is
    // deterministic for a given program + model).
    let hits: HashSet<TaskId> = if cm.cache_hit_rate > 0.0 {
        let mut rng = Rng::new(0xCAC4E);
        program
            .tasks()
            .iter()
            .filter(|t| t.is_pure() && rng.chance(cm.cache_hit_rate))
            .map(|t| t.id)
            .collect()
    } else {
        HashSet::new()
    };

    let push = |heap: &mut BinaryHeap<QEv>, t: u64, ev: Ev, seq: &mut u64| {
        heap.push(QEv { t, seq: *seq, ev });
        *seq += 1;
    };

    // initial assignments
    pump(
        program, cm, cfg, &mut state, &mut inflight, now, &mut heap, &mut seq, &mut bytes,
        &hits,
    );

    while let Some(QEv { t, ev, .. }) = heap.pop() {
        debug_assert!(t >= now, "time went backwards");
        now = t;
        match ev {
            Ev::Arrive(w, task) => {
                let start = now.max(free_at[w.index()]);
                let cost = cm.task_cost_ns(program.task(task));
                let end = start + cost;
                free_at[w.index()] = end;
                trace.push(TraceEvent {
                    task,
                    worker: w,
                    start_ns: start,
                    end_ns: end,
                });
                push(&mut heap, end, Ev::Computed(w, task), &mut seq);
            }
            Ev::Computed(w, task) => {
                let out_bytes: u64 = program.task(task).est.bytes_out;
                let dt = if cfg.transfer_free {
                    0
                } else {
                    bytes += out_bytes;
                    cm.transfer_ns(out_bytes)
                };
                push(&mut heap, now + dt, Ev::LeaderSees(w, task), &mut seq);
            }
            Ev::LeaderSees(w, task) => {
                inflight[w.index()] -= 1;
                state.on_done(program, task, w);
                pump(
                    program, cm, cfg, &mut state, &mut inflight, now, &mut heap, &mut seq,
                    &mut bytes, &hits,
                );
            }
            Ev::CacheServed(task) => {
                trace.record_cache_hit(task);
                state.complete_local(program, task);
                pump(
                    program, cm, cfg, &mut state, &mut inflight, now, &mut heap, &mut seq,
                    &mut bytes, &hits,
                );
            }
        }
    }

    anyhow::ensure!(
        state.is_done(),
        "simulation stalled with {} tasks incomplete",
        program.len() - state.completed()
    );
    if cm.cache_hit_rate > 0.0 {
        let pure = program.tasks().iter().filter(|t| t.is_pure()).count() as u64;
        trace.cache_misses = pure - trace.cache_hits;
    }
    let makespan = now;
    trace.wall_ns = makespan;
    trace.bytes_transferred = bytes;
    let busy: u64 = trace.busy_ns().iter().sum();
    Ok(SimResult {
        makespan_ns: makespan,
        utilization: if makespan > 0 {
            busy as f64 / (makespan as f64 * cfg.n_workers as f64)
        } else {
            0.0
        },
        trace,
        bytes_transferred: bytes,
    })
}

#[allow(clippy::too_many_arguments)]
fn pump(
    program: &TaskProgram,
    cm: &CostModel,
    cfg: &SimConfig,
    state: &mut GreedyState,
    inflight: &mut [usize],
    now: u64,
    heap: &mut BinaryHeap<QEv>,
    seq: &mut u64,
    bytes: &mut u64,
    hits: &HashSet<TaskId>,
) {
    let mut dispatch_t = now;
    loop {
        let has_capacity = (0..cfg.n_workers).any(|w| inflight[w] < cfg.pipeline_depth);
        if !has_capacity || state.n_ready() == 0 {
            return;
        }
        let Some((mut task, mut w)) = state.assign_next(program) else {
            return;
        };
        if inflight[w.index()] >= cfg.pipeline_depth {
            state.unassign(program, task, w);
            let w2 = (0..cfg.n_workers)
                .filter(|i| inflight[*i] < cfg.pipeline_depth)
                .min_by_key(|i| inflight[*i])
                .unwrap();
            // dispatch the (new) top of the heap, pinned to w2 — it may
            // differ from `task` under priority ties
            let Some(t2) = state.assign_to(program, WorkerId(w2 as u32)) else {
                return;
            };
            task = t2;
            w = WorkerId(w2 as u32);
        }
        // modeled warm cache: the leader serves hits without dispatching
        if hits.contains(&task) {
            state.abort_assign(w);
            heap.push(QEv {
                t: dispatch_t + cm.cache_serve_ns,
                seq: *seq,
                ev: Ev::CacheServed(task),
            });
            *seq += 1;
            continue;
        }
        inflight[w.index()] += 1;
        // argument bytes that must travel: inputs whose producer is not w
        let arrive = if cfg.transfer_free {
            dispatch_t
        } else {
            dispatch_t += cm.dispatch_ns; // leader serializes dispatches
            let spec = program.task(task);
            let mut wire_bytes = 0u64;
            for a in &spec.args {
                if let crate::ir::task::ArgRef::Output { task: d, .. } = a {
                    if state.location(*d) != Some(w) {
                        wire_bytes += program.task(*d).est.bytes_out;
                    }
                }
            }
            // constants travel too (seeds: negligible but accounted)
            wire_bytes += spec
                .args
                .iter()
                .filter(|a| matches!(a, crate::ir::task::ArgRef::Const(_)))
                .count() as u64
                * 8;
            *bytes += wire_bytes;
            dispatch_t + cm.transfer_ns(wire_bytes)
        };
        heap.push(QEv {
            t: arrive,
            seq: *seq,
            ev: Ev::Arrive(w, task),
        });
        *seq += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::task::{ArgRef, CombineKind, CostEst, OpKind};
    use crate::ir::ProgramBuilder;

    /// t independent rounds of gen+gen+mul+sum (the Figure 2 workload).
    pub fn rounds_program(t: usize, n: usize) -> TaskProgram {
        let nn = (n * n * 4) as u64;
        let mut b = ProgramBuilder::new();
        let mut sums = Vec::new();
        for r in 0..t {
            let g1 = b.push(
                OpKind::Artifact { name: format!("matgen_{n}") },
                vec![ArgRef::const_i32(2 * r as i32)],
                1,
                CostEst { flops: 8 * (n * n) as u64, bytes_in: 4, bytes_out: nn },
                format!("a{r}"),
            );
            let g2 = b.push(
                OpKind::Artifact { name: format!("matgen_{n}") },
                vec![ArgRef::const_i32(2 * r as i32 + 1)],
                1,
                CostEst { flops: 8 * (n * n) as u64, bytes_in: 4, bytes_out: nn },
                format!("b{r}"),
            );
            let mm = b.push(
                OpKind::Artifact { name: format!("matmul_{n}") },
                vec![ArgRef::out(g1, 0), ArgRef::out(g2, 0)],
                1,
                CostEst { flops: 2 * (n as u64).pow(3), bytes_in: 2 * nn, bytes_out: nn },
                format!("c{r}"),
            );
            let s = b.push(
                OpKind::Artifact { name: format!("matsum_{n}") },
                vec![ArgRef::out(mm, 0)],
                1,
                CostEst { flops: 2 * (n * n) as u64, bytes_in: nn, bytes_out: 4 },
                format!("s{r}"),
            );
            sums.push(ArgRef::out(s, 0));
        }
        let total = b.push(
            OpKind::Combine(CombineKind::AddScalars),
            sums,
            1,
            CostEst::ZERO,
            "total",
        );
        b.mark_output(ArgRef::out(total, 0));
        b.build().unwrap()
    }

    #[test]
    fn trace_is_valid_and_deterministic() {
        let p = rounds_program(8, 64);
        let cm = CostModel::default();
        let r1 = simulate(&p, &cm, &SimConfig::cluster(4)).unwrap();
        let r2 = simulate(&p, &cm, &SimConfig::cluster(4)).unwrap();
        r1.trace.validate(&p).unwrap();
        assert_eq!(r1.makespan_ns, r2.makespan_ns);
        assert_eq!(r1.bytes_transferred, r2.bytes_transferred);
    }

    #[test]
    fn more_workers_never_slower_on_parallel_workload() {
        let p = rounds_program(16, 64);
        let cm = CostModel::default();
        let times: Vec<u64> = [1usize, 2, 4, 8]
            .iter()
            .map(|w| simulate(&p, &cm, &SimConfig::cluster(*w)).unwrap().makespan_ns)
            .collect();
        for pair in times.windows(2) {
            assert!(pair[1] <= pair[0] + pair[0] / 10, "{times:?}");
        }
        // and meaningful speedup 1 -> 4 workers on 16 independent rounds
        assert!(
            (times[0] as f64) / (times[2] as f64) > 2.0,
            "expected >2x speedup: {times:?}"
        );
    }

    #[test]
    fn smp_beats_cluster_at_same_width() {
        // shared memory has no transfer cost, so it must win
        let p = rounds_program(8, 64);
        let cm = CostModel::default();
        let smp = simulate(&p, &cm, &SimConfig::smp(4)).unwrap();
        let dist = simulate(&p, &cm, &SimConfig::cluster(4)).unwrap();
        assert!(smp.makespan_ns < dist.makespan_ns);
        assert_eq!(smp.bytes_transferred, 0);
        assert!(dist.bytes_transferred > 0);
    }

    #[test]
    fn chain_gets_no_speedup() {
        let mut b = ProgramBuilder::new();
        let mut prev = b.push(
            OpKind::Synthetic { compute_us: 100 },
            vec![],
            1,
            CostEst { flops: 0, bytes_in: 0, bytes_out: 8 },
            "t0",
        );
        for i in 1..10 {
            prev = b.push(
                OpKind::Synthetic { compute_us: 100 },
                vec![ArgRef::out(prev, 0)],
                1,
                CostEst { flops: 0, bytes_in: 8, bytes_out: 8 },
                format!("t{i}"),
            );
        }
        let p = b.build().unwrap();
        let cm = CostModel::default();
        let t1 = simulate(&p, &cm, &SimConfig::smp(1)).unwrap().makespan_ns;
        let t4 = simulate(&p, &cm, &SimConfig::smp(4)).unwrap().makespan_ns;
        assert_eq!(t1, t4); // span-bound
    }

    #[test]
    fn measured_costs_change_makespan() {
        let p = rounds_program(4, 64);
        let mut cm = CostModel::default();
        let base = simulate(&p, &cm, &SimConfig::cluster(2)).unwrap().makespan_ns;
        cm.set_measured("matmul_64", 50_000_000); // pretend matmul is huge
        let slow = simulate(&p, &cm, &SimConfig::cluster(2)).unwrap().makespan_ns;
        assert!(slow > base * 5, "{slow} vs {base}");
    }

    #[test]
    fn locality_placement_reduces_bytes() {
        let p = rounds_program(8, 128);
        let cm = CostModel::default();
        let ll = SimConfig {
            placement: PlacementPolicy::LeastLoaded,
            ..SimConfig::cluster(4)
        };
        let loc = SimConfig {
            placement: PlacementPolicy::LocalityAware,
            ..SimConfig::cluster(4)
        };
        let r_ll = simulate(&p, &cm, &ll).unwrap();
        let r_loc = simulate(&p, &cm, &loc).unwrap();
        assert!(
            r_loc.bytes_transferred <= r_ll.bytes_transferred,
            "locality {} vs least-loaded {}",
            r_loc.bytes_transferred,
            r_ll.bytes_transferred
        );
    }

    #[test]
    fn warm_cache_model_shrinks_makespan_and_is_deterministic() {
        let p = rounds_program(8, 64);
        let cold = simulate(&p, &CostModel::default(), &SimConfig::cluster(4)).unwrap();
        assert_eq!(cold.trace.cache_hits, 0);

        let mut half = CostModel::default();
        half.cache_hit_rate = 0.5;
        let r_half = simulate(&p, &half, &SimConfig::cluster(4)).unwrap();
        r_half.trace.validate(&p).unwrap();
        assert!(r_half.trace.cache_hits > 0, "rate 0.5 over 33 tasks must hit");
        assert_eq!(
            r_half.trace.cache_hits + r_half.trace.cache_misses,
            p.len() as u64,
            "every task in this all-pure program is accounted hit or miss"
        );
        // removing half the work should not meaningfully hurt (small slack
        // for scheduling anomalies)
        assert!(
            r_half.makespan_ns as f64 <= cold.makespan_ns as f64 * 1.1,
            "half-warm {} vs cold {}",
            r_half.makespan_ns,
            cold.makespan_ns
        );

        let mut full = CostModel::default();
        full.cache_hit_rate = 1.0;
        let r_full = simulate(&p, &full, &SimConfig::cluster(4)).unwrap();
        r_full.trace.validate(&p).unwrap();
        assert_eq!(r_full.trace.executed_tasks(), 0, "fully warm: nothing executes");
        assert_eq!(r_full.trace.cache_hits, p.len() as u64);
        assert_eq!(r_full.bytes_transferred, 0);
        assert!(r_full.makespan_ns < cold.makespan_ns);

        // deterministic for a fixed (program, model, config)
        let again = simulate(&p, &half, &SimConfig::cluster(4)).unwrap();
        assert_eq!(again.makespan_ns, r_half.makespan_ns);
        assert_eq!(again.trace.cache_hits, r_half.trace.cache_hits);
    }

    #[test]
    fn utilization_bounded() {
        let p = rounds_program(8, 64);
        let cm = CostModel::default();
        let r = simulate(&p, &cm, &SimConfig::cluster(2)).unwrap();
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
    }
}
