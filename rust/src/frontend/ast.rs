//! HaskLite abstract syntax.

use super::span::Span;

/// A whole module/program.
#[derive(Clone, Debug, PartialEq)]
pub struct Program {
    pub decls: Vec<Decl>,
}

impl Program {
    pub fn type_sigs(&self) -> impl Iterator<Item = (&str, &TypeExpr)> {
        self.decls.iter().filter_map(|d| match d {
            Decl::TypeSig { name, ty, .. } => Some((name.as_str(), ty)),
            _ => None,
        })
    }

    pub fn fun_defs(&self) -> impl Iterator<Item = (&str, &[String], &Body)> {
        self.decls.iter().filter_map(|d| match d {
            Decl::FunDef {
                name, params, body, ..
            } => Some((name.as_str(), params.as_slice(), body)),
            _ => None,
        })
    }

    pub fn find_fun(&self, name: &str) -> Option<(&[String], &Body)> {
        self.fun_defs()
            .find(|(n, _, _)| *n == name)
            .map(|(_, p, b)| (p, b))
    }

    pub fn find_sig(&self, name: &str) -> Option<&TypeExpr> {
        self.type_sigs().find(|(n, _)| *n == name).map(|(_, t)| t)
    }
}

/// Top-level declaration.
#[derive(Clone, Debug, PartialEq)]
pub enum Decl {
    /// `data Summary = ...` — constructors are opaque to the parallelizer.
    DataDecl { name: String, span: Span },
    /// `f :: T`
    TypeSig {
        name: String,
        ty: TypeExpr,
        span: Span,
    },
    /// `f x y = body`
    FunDef {
        name: String,
        params: Vec<String>,
        body: Body,
        span: Span,
    },
}

impl Decl {
    pub fn name(&self) -> &str {
        match self {
            Decl::DataDecl { name, .. }
            | Decl::TypeSig { name, .. }
            | Decl::FunDef { name, .. } => name,
        }
    }

    pub fn span(&self) -> Span {
        match self {
            Decl::DataDecl { span, .. }
            | Decl::TypeSig { span, .. }
            | Decl::FunDef { span, .. } => *span,
        }
    }
}

/// Function body: expression or do-block.
#[derive(Clone, Debug, PartialEq)]
pub enum Body {
    Expr(Expr),
    Do(Vec<Stmt>),
}

/// A statement in a `do` block.
#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// `x <- expr` — monadic bind (impure right-hand side).
    Bind { name: String, expr: Expr, span: Span },
    /// `let x = expr` — pure binding.
    Let { name: String, expr: Expr, span: Span },
    /// bare expression statement (e.g. `print (y, z)`).
    Expr { expr: Expr, span: Span },
}

impl Stmt {
    pub fn span(&self) -> Span {
        match self {
            Stmt::Bind { span, .. } | Stmt::Let { span, .. } | Stmt::Expr { span, .. } => *span,
        }
    }

    pub fn bound_name(&self) -> Option<&str> {
        match self {
            Stmt::Bind { name, .. } | Stmt::Let { name, .. } => Some(name),
            Stmt::Expr { .. } => None,
        }
    }

    pub fn expr(&self) -> &Expr {
        match self {
            Stmt::Bind { expr, .. } | Stmt::Let { expr, .. } | Stmt::Expr { expr, .. } => expr,
        }
    }
}

/// Expressions.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// Variable or function reference (lowercase).
    Var { name: String, span: Span },
    /// Data constructor reference (uppercase) — opaque value.
    Con { name: String, span: Span },
    Int { value: i64, span: Span },
    Float { value: f64, span: Span },
    Str { value: String, span: Span },
    /// Unit literal `()`.
    Unit { span: Span },
    /// Application `f a b` (head + ≥1 args).
    App {
        func: Box<Expr>,
        args: Vec<Expr>,
        span: Span,
    },
    /// Binary operator `a + b`.
    BinOp {
        op: String,
        lhs: Box<Expr>,
        rhs: Box<Expr>,
        span: Span,
    },
    /// Tuple `(a, b, ...)`.
    Tuple { items: Vec<Expr>, span: Span },
}

impl Expr {
    pub fn span(&self) -> Span {
        match self {
            Expr::Var { span, .. }
            | Expr::Con { span, .. }
            | Expr::Int { span, .. }
            | Expr::Float { span, .. }
            | Expr::Str { span, .. }
            | Expr::Unit { span }
            | Expr::App { span, .. }
            | Expr::BinOp { span, .. }
            | Expr::Tuple { span, .. } => *span,
        }
    }

    /// All variable names referenced (free-variable approximation: HaskLite
    /// expressions have no binders).
    pub fn vars(&self) -> Vec<&str> {
        let mut out = Vec::new();
        self.collect_vars(&mut out);
        out
    }

    fn collect_vars<'a>(&'a self, out: &mut Vec<&'a str>) {
        match self {
            Expr::Var { name, .. } => out.push(name),
            Expr::App { func, args, .. } => {
                func.collect_vars(out);
                for a in args {
                    a.collect_vars(out);
                }
            }
            Expr::BinOp { lhs, rhs, .. } => {
                lhs.collect_vars(out);
                rhs.collect_vars(out);
            }
            Expr::Tuple { items, .. } => {
                for i in items {
                    i.collect_vars(out);
                }
            }
            _ => {}
        }
    }

    /// If this is a call `f a₁ … aₙ` (or a bare var = nullary call),
    /// return the head name and args.
    pub fn as_call(&self) -> Option<(&str, &[Expr])> {
        match self {
            Expr::Var { name, .. } => Some((name, &[])),
            Expr::App { func, args, .. } => match func.as_ref() {
                Expr::Var { name, .. } => Some((name, args)),
                _ => None,
            },
            _ => None,
        }
    }
}

/// Type expressions from signatures.
#[derive(Clone, Debug, PartialEq)]
pub enum TypeExpr {
    /// Type constructor possibly applied: `Int`, `IO Summary`, `Maybe a`.
    Con { name: String, args: Vec<TypeExpr> },
    /// Type variable (lowercase).
    Var(String),
    /// Function arrow (right-assoc).
    Arrow(Box<TypeExpr>, Box<TypeExpr>),
    /// Tuple type.
    Tuple(Vec<TypeExpr>),
    /// `()`
    Unit,
}

impl TypeExpr {
    /// Result type after consuming all arrows.
    pub fn result(&self) -> &TypeExpr {
        match self {
            TypeExpr::Arrow(_, r) => r.result(),
            t => t,
        }
    }

    /// Argument types, left to right.
    pub fn params(&self) -> Vec<&TypeExpr> {
        let mut out = Vec::new();
        let mut cur = self;
        while let TypeExpr::Arrow(a, r) = cur {
            out.push(a.as_ref());
            cur = r;
        }
        out
    }

    /// The paper's purity rule: impure ⇔ the *result* type is `IO t`.
    pub fn is_io(&self) -> bool {
        matches!(self.result(), TypeExpr::Con { name, .. } if name == "IO")
    }

    pub fn arity(&self) -> usize {
        self.params().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn con(name: &str) -> TypeExpr {
        TypeExpr::Con {
            name: name.into(),
            args: vec![],
        }
    }

    #[test]
    fn purity_from_result_type() {
        // Summary -> Int : pure
        let t = TypeExpr::Arrow(Box::new(con("Summary")), Box::new(con("Int")));
        assert!(!t.is_io());
        assert_eq!(t.arity(), 1);

        // IO Summary : impure
        let t = TypeExpr::Con {
            name: "IO".into(),
            args: vec![con("Summary")],
        };
        assert!(t.is_io());
        assert_eq!(t.arity(), 0);

        // Int -> IO () : impure with one param
        let io_unit = TypeExpr::Con {
            name: "IO".into(),
            args: vec![TypeExpr::Unit],
        };
        let t = TypeExpr::Arrow(Box::new(con("Int")), Box::new(io_unit));
        assert!(t.is_io());
        assert_eq!(t.arity(), 1);
    }

    #[test]
    fn expr_vars_and_calls() {
        let e = Expr::App {
            func: Box::new(Expr::Var {
                name: "f".into(),
                span: Span::DUMMY,
            }),
            args: vec![
                Expr::Var {
                    name: "x".into(),
                    span: Span::DUMMY,
                },
                Expr::Int {
                    value: 3,
                    span: Span::DUMMY,
                },
            ],
            span: Span::DUMMY,
        };
        assert_eq!(e.vars(), vec!["f", "x"]);
        let (head, args) = e.as_call().unwrap();
        assert_eq!(head, "f");
        assert_eq!(args.len(), 2);
    }
}
