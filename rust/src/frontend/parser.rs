//! Recursive-descent parser for HaskLite.
//!
//! Grammar (one statement per logical line; `do` blocks extend while lines
//! are indented deeper than column 1):
//!
//! ```text
//! program  := { decl NEWLINE }
//! decl     := 'data' Upper '=' <rest of line>
//!           | lower '::' type
//!           | lower { lower } '=' ('do' NEWLINE { stmt NEWLINE } | expr)
//! type     := btype [ '->' type ]
//! btype    := atype { atype }                 -- constructor application
//! atype    := Upper | lower | '(' ')' | '(' type { ',' type } ')' | '[' type ']'
//! stmt     := lower '<-' expr | 'let' lower '=' expr | expr
//! expr     := app { binop app }               -- left-assoc, no precedence
//!                                             -- tower (documented)
//! app      := atom { atom }
//! atom     := lower | Upper | INT | FLOAT | STRING
//!           | '(' ')' | '(' expr { ',' expr } ')'
//! ```

use super::ast::*;
use super::diag::Diagnostic;
use super::lexer::lex;
use super::span::Span;
use super::token::{Tok, Token};

/// Parse a full HaskLite program.
pub fn parse_program(src: &str) -> Result<Program, Diagnostic> {
    let tokens = lex(src)?;
    let mut p = Parser { tokens, pos: 0 };
    p.program()
}

/// Parse a type expression alone (used by tests and the registry tooling).
pub fn parse_type(src: &str) -> Result<TypeExpr, Diagnostic> {
    let tokens = lex(src)?;
    let mut p = Parser { tokens, pos: 0 };
    let t = p.ty()?;
    p.skip_newlines();
    p.expect_eof()?;
    Ok(t)
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.tokens[self.pos].tok
    }

    fn peek_span(&self) -> Span {
        self.tokens[self.pos].span
    }

    fn peek2(&self) -> &Tok {
        self.tokens
            .get(self.pos + 1)
            .map(|t| &t.tok)
            .unwrap_or(&Tok::Eof)
    }

    fn bump(&mut self) -> Token {
        let t = self.tokens[self.pos].clone();
        if self.pos + 1 < self.tokens.len() {
            self.pos += 1;
        }
        t
    }

    fn err(&self, msg: impl Into<String>) -> Diagnostic {
        Diagnostic::new(msg, self.peek_span())
    }

    fn expect(&mut self, tok: &Tok) -> Result<Token, Diagnostic> {
        if self.peek() == tok {
            Ok(self.bump())
        } else {
            Err(self.err(format!(
                "expected {}, found {}",
                tok.describe(),
                self.peek().describe()
            )))
        }
    }

    fn expect_eof(&self) -> Result<(), Diagnostic> {
        if matches!(self.peek(), Tok::Eof) {
            Ok(())
        } else {
            Err(self.err(format!("expected end of input, found {}", self.peek().describe())))
        }
    }

    fn skip_newlines(&mut self) {
        while matches!(self.peek(), Tok::Newline) {
            self.bump();
        }
    }

    fn lower_name(&mut self) -> Result<(String, Span), Diagnostic> {
        match self.peek().clone() {
            Tok::Lower(name) => {
                let sp = self.peek_span();
                self.bump();
                Ok((name, sp))
            }
            other => Err(self.err(format!("expected a lowercase name, found {}", other.describe()))),
        }
    }

    // -- declarations --------------------------------------------------------

    fn program(&mut self) -> Result<Program, Diagnostic> {
        let mut decls = Vec::new();
        self.skip_newlines();
        while !matches!(self.peek(), Tok::Eof) {
            decls.push(self.decl()?);
            self.skip_newlines();
        }
        Ok(Program { decls })
    }

    fn decl(&mut self) -> Result<Decl, Diagnostic> {
        match self.peek() {
            Tok::Data => self.data_decl(),
            Tok::Lower(_) => {
                if matches!(self.peek2(), Tok::DColon) {
                    self.type_sig()
                } else {
                    self.fun_def()
                }
            }
            other => Err(self.err(format!(
                "expected a declaration, found {}",
                other.describe()
            ))),
        }
    }

    fn data_decl(&mut self) -> Result<Decl, Diagnostic> {
        let start = self.peek_span();
        self.expect(&Tok::Data)?;
        let name = match self.peek().clone() {
            Tok::Upper(n) => {
                self.bump();
                n
            }
            other => {
                return Err(self.err(format!(
                    "expected a type name after `data`, found {}",
                    other.describe()
                )))
            }
        };
        // constructors are opaque: consume to end of line
        let mut end = start;
        while !matches!(self.peek(), Tok::Newline | Tok::Eof) {
            end = self.peek_span();
            self.bump();
        }
        Ok(Decl::DataDecl {
            name,
            span: start.to(end),
        })
    }

    fn type_sig(&mut self) -> Result<Decl, Diagnostic> {
        let (name, start) = self.lower_name()?;
        self.expect(&Tok::DColon)?;
        let ty = self.ty()?;
        let end = self.peek_span();
        Ok(Decl::TypeSig {
            name,
            ty,
            span: start.to(end),
        })
    }

    fn fun_def(&mut self) -> Result<Decl, Diagnostic> {
        let (name, start) = self.peek_decl_column_guard()?;
        let mut params = Vec::new();
        while let Tok::Lower(p) = self.peek().clone() {
            params.push(p);
            self.bump();
        }
        self.expect(&Tok::Equals)?;
        let body = if matches!(self.peek(), Tok::Do) {
            self.bump();
            self.expect(&Tok::Newline)?;
            Body::Do(self.do_block()?)
        } else {
            Body::Expr(self.expr()?)
        };
        let end = self.peek_span();
        Ok(Decl::FunDef {
            name,
            params,
            body,
            span: start.to(end),
        })
    }

    /// Function name of a definition; enforces the layout rule that
    /// declarations start at column 1.
    fn peek_decl_column_guard(&mut self) -> Result<(String, Span), Diagnostic> {
        let sp = self.peek_span();
        if sp.col != 1 {
            return Err(self.err(
                "declarations must start at column 1 (HaskLite layout rule)",
            ));
        }
        self.lower_name()
    }

    fn do_block(&mut self) -> Result<Vec<Stmt>, Diagnostic> {
        let mut stmts = Vec::new();
        loop {
            self.skip_newlines();
            if matches!(self.peek(), Tok::Eof) {
                break;
            }
            // Block ends when a line returns to column 1 (next declaration).
            if self.peek_span().col == 1 {
                break;
            }
            stmts.push(self.stmt()?);
            if !matches!(self.peek(), Tok::Eof) {
                self.expect(&Tok::Newline)?;
            }
        }
        if stmts.is_empty() {
            return Err(self.err("empty `do` block"));
        }
        Ok(stmts)
    }

    // -- statements ----------------------------------------------------------

    fn stmt(&mut self) -> Result<Stmt, Diagnostic> {
        let start = self.peek_span();
        match self.peek() {
            Tok::Let => {
                self.bump();
                let (name, _) = self.lower_name()?;
                self.expect(&Tok::Equals)?;
                let expr = self.expr()?;
                let span = start.to(expr.span());
                Ok(Stmt::Let { name, expr, span })
            }
            Tok::Lower(_) if matches!(self.peek2(), Tok::LArrow) => {
                let (name, _) = self.lower_name()?;
                self.expect(&Tok::LArrow)?;
                let expr = self.expr()?;
                let span = start.to(expr.span());
                Ok(Stmt::Bind { name, expr, span })
            }
            _ => {
                let expr = self.expr()?;
                let span = start.to(expr.span());
                Ok(Stmt::Expr { expr, span })
            }
        }
    }

    // -- expressions ---------------------------------------------------------

    fn expr(&mut self) -> Result<Expr, Diagnostic> {
        let mut lhs = self.app()?;
        while let Tok::Op(op) = self.peek().clone() {
            self.bump();
            let rhs = self.app()?;
            let span = lhs.span().to(rhs.span());
            lhs = Expr::BinOp {
                op,
                lhs: Box::new(lhs),
                rhs: Box::new(rhs),
                span,
            };
        }
        Ok(lhs)
    }

    fn app(&mut self) -> Result<Expr, Diagnostic> {
        let head = self.atom()?;
        let mut args = Vec::new();
        while self.starts_atom() {
            args.push(self.atom()?);
        }
        if args.is_empty() {
            Ok(head)
        } else {
            let span = head.span().to(args.last().unwrap().span());
            Ok(Expr::App {
                func: Box::new(head),
                args,
                span,
            })
        }
    }

    fn starts_atom(&self) -> bool {
        matches!(
            self.peek(),
            Tok::Lower(_) | Tok::Upper(_) | Tok::Int(_) | Tok::Float(_) | Tok::Str(_) | Tok::LParen
        )
    }

    fn atom(&mut self) -> Result<Expr, Diagnostic> {
        let span = self.peek_span();
        match self.peek().clone() {
            Tok::Lower(name) => {
                self.bump();
                Ok(Expr::Var { name, span })
            }
            Tok::Upper(name) => {
                self.bump();
                Ok(Expr::Con { name, span })
            }
            Tok::Int(value) => {
                self.bump();
                Ok(Expr::Int { value, span })
            }
            Tok::Float(value) => {
                self.bump();
                Ok(Expr::Float { value, span })
            }
            Tok::Str(value) => {
                self.bump();
                Ok(Expr::Str { value, span })
            }
            Tok::LParen => {
                self.bump();
                if matches!(self.peek(), Tok::RParen) {
                    let end = self.bump().span;
                    return Ok(Expr::Unit {
                        span: span.to(end),
                    });
                }
                let first = self.expr()?;
                let mut items = vec![first];
                while matches!(self.peek(), Tok::Comma) {
                    self.bump();
                    items.push(self.expr()?);
                }
                let end = self.expect(&Tok::RParen)?.span;
                if items.len() == 1 {
                    Ok(items.pop().unwrap()) // parenthesized expr
                } else {
                    Ok(Expr::Tuple {
                        items,
                        span: span.to(end),
                    })
                }
            }
            other => Err(self.err(format!("expected an expression, found {}", other.describe()))),
        }
    }

    // -- types ----------------------------------------------------------------

    fn ty(&mut self) -> Result<TypeExpr, Diagnostic> {
        let lhs = self.btype()?;
        if matches!(self.peek(), Tok::RArrow) {
            self.bump();
            let rhs = self.ty()?; // right-assoc
            Ok(TypeExpr::Arrow(Box::new(lhs), Box::new(rhs)))
        } else {
            Ok(lhs)
        }
    }

    fn btype(&mut self) -> Result<TypeExpr, Diagnostic> {
        let head = self.atype()?;
        let mut args = Vec::new();
        while self.starts_atype() {
            args.push(self.atype()?);
        }
        if args.is_empty() {
            return Ok(head);
        }
        match head {
            TypeExpr::Con { name, args: mut a0 } => {
                a0.extend(args);
                Ok(TypeExpr::Con { name, args: a0 })
            }
            _ => Err(self.err("only type constructors can be applied")),
        }
    }

    fn starts_atype(&self) -> bool {
        matches!(
            self.peek(),
            Tok::Upper(_) | Tok::Lower(_) | Tok::LParen | Tok::LBracket
        )
    }

    fn atype(&mut self) -> Result<TypeExpr, Diagnostic> {
        match self.peek().clone() {
            Tok::Upper(name) => {
                self.bump();
                Ok(TypeExpr::Con { name, args: vec![] })
            }
            Tok::Lower(name) => {
                self.bump();
                Ok(TypeExpr::Var(name))
            }
            Tok::LBracket => {
                self.bump();
                let inner = self.ty()?;
                self.expect(&Tok::RBracket)?;
                Ok(TypeExpr::Con {
                    name: "List".into(),
                    args: vec![inner],
                })
            }
            Tok::LParen => {
                self.bump();
                if matches!(self.peek(), Tok::RParen) {
                    self.bump();
                    return Ok(TypeExpr::Unit);
                }
                let first = self.ty()?;
                let mut items = vec![first];
                while matches!(self.peek(), Tok::Comma) {
                    self.bump();
                    items.push(self.ty()?);
                }
                self.expect(&Tok::RParen)?;
                if items.len() == 1 {
                    Ok(items.pop().unwrap())
                } else {
                    Ok(TypeExpr::Tuple(items))
                }
            }
            other => Err(self.err(format!("expected a type, found {}", other.describe()))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's §2 program, verbatim modulo the elided bodies.
    pub const NLP_EXAMPLE: &str = r#"
data Summary = Opaque

clean_files :: IO Summary
clean_files = primClean

complex_evaluation :: Summary -> Int
complex_evaluation x = primEval x

semantic_analysis :: IO Int
semantic_analysis = primSem

main :: IO ()
main = do
  x <- clean_files
  let y = complex_evaluation x
  z <- semantic_analysis
  print (y, z)
"#;

    #[test]
    fn parses_paper_example() {
        let p = parse_program(NLP_EXAMPLE).unwrap();
        assert_eq!(p.decls.len(), 9);
        let (params, body) = p.find_fun("main").unwrap();
        assert!(params.is_empty());
        let Body::Do(stmts) = body else {
            panic!("main should be a do block")
        };
        assert_eq!(stmts.len(), 4);
        assert_eq!(stmts[0].bound_name(), Some("x"));
        assert!(matches!(stmts[1], Stmt::Let { .. }));
        assert_eq!(stmts[2].bound_name(), Some("z"));
        assert!(matches!(stmts[3], Stmt::Expr { .. }));
        // print (y, z) is an application of print to a tuple
        let (head, args) = stmts[3].expr().as_call().unwrap();
        assert_eq!(head, "print");
        assert!(matches!(args[0], Expr::Tuple { .. }));
    }

    #[test]
    fn signature_types() {
        let p = parse_program(NLP_EXAMPLE).unwrap();
        assert!(p.find_sig("clean_files").unwrap().is_io());
        assert!(!p.find_sig("complex_evaluation").unwrap().is_io());
        assert_eq!(p.find_sig("complex_evaluation").unwrap().arity(), 1);
        assert!(p.find_sig("main").unwrap().is_io());
    }

    #[test]
    fn parses_multi_arg_application_and_operators() {
        let p = parse_program("f :: Int -> Int -> Int\nr = f 1 2 + f 3 4\n").unwrap();
        let (_, body) = p.find_fun("r").unwrap();
        let Body::Expr(Expr::BinOp { op, lhs, rhs, .. }) = body else {
            panic!("expected binop, got {body:?}")
        };
        assert_eq!(op, "+");
        assert!(matches!(**lhs, Expr::App { .. }));
        assert!(matches!(**rhs, Expr::App { .. }));
    }

    #[test]
    fn parses_params() {
        let p = parse_program("g a b = a\n").unwrap();
        let (params, _) = p.find_fun("g").unwrap();
        assert_eq!(params, &["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn nested_io_type() {
        let t = parse_type("Int -> IO (Int, Summary)").unwrap();
        assert!(t.is_io());
        assert_eq!(t.arity(), 1);
        let TypeExpr::Con { name, args } = t.result() else {
            panic!()
        };
        assert_eq!(name, "IO");
        assert!(matches!(args[0], TypeExpr::Tuple(_)));
    }

    #[test]
    fn list_type_sugar() {
        let t = parse_type("[Int] -> Int").unwrap();
        let p = t.params();
        assert!(matches!(p[0], TypeExpr::Con { name, .. } if name == "List"));
    }

    #[test]
    fn error_messages_have_spans() {
        let err = parse_program("main = do\n  x <- \n").unwrap_err();
        assert!(err.span.line >= 2, "{err}");
        let rendered = err.render("main = do\n  x <- \n");
        assert!(rendered.contains('^'));
    }

    #[test]
    fn empty_do_block_rejected() {
        assert!(parse_program("main = do\n").is_err());
    }

    #[test]
    fn indented_declaration_rejected() {
        assert!(parse_program("  f = 1\n").is_err());
    }

    #[test]
    fn multiline_tuple_in_parens() {
        let p = parse_program("main = do\n  print (1,\n          2)\n").unwrap();
        let (_, body) = p.find_fun("main").unwrap();
        let Body::Do(stmts) = body else { panic!() };
        assert_eq!(stmts.len(), 1);
    }
}
