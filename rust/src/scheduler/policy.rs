//! Placement and stealing policies (Ablations A and B).

use crate::ir::task::{ShardInfo, ShardRole, TaskId};
use crate::util::rng::Rng;

use super::WorkerId;

/// Which worker a ready task is assigned to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PlacementPolicy {
    /// Cycle through workers regardless of load.
    RoundRobin,
    /// Fewest queued+running tasks.
    LeastLoaded,
    /// Prefer workers already holding the task's inputs (falls back to
    /// least-loaded among ties) — only meaningful with worker-side caching.
    LocalityAware,
    /// Shard-aware locality: sibling shards of one partition family spread
    /// deterministically across live workers, while combines and other
    /// consumers co-locate with their producers (the `LocalityAware`
    /// rule). The policy the partition rewrite is designed for.
    ShardAffinity,
}

impl PlacementPolicy {
    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s {
            "round-robin" | "rr" => Some(PlacementPolicy::RoundRobin),
            "least-loaded" | "ll" => Some(PlacementPolicy::LeastLoaded),
            "locality" | "loc" => Some(PlacementPolicy::LocalityAware),
            "shard" | "affinity" => Some(PlacementPolicy::ShardAffinity),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::LocalityAware => "locality",
            PlacementPolicy::ShardAffinity => "shard",
        }
    }
}

/// How an idle worker (or the leader on its behalf) picks a steal victim.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum StealPolicy {
    /// No stealing: tasks stay where they were placed.
    None,
    /// Uniformly random victim (classic Cilk/BLumofe-Leiserson).
    RandomVictim,
    /// The worker with the deepest queue.
    RichestVictim,
}

impl StealPolicy {
    pub fn parse(s: &str) -> Option<StealPolicy> {
        match s {
            "none" => Some(StealPolicy::None),
            "random" => Some(StealPolicy::RandomVictim),
            "richest" => Some(StealPolicy::RichestVictim),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StealPolicy::None => "none",
            StealPolicy::RandomVictim => "random",
            StealPolicy::RichestVictim => "richest",
        }
    }

    /// Choose a victim for `thief` among workers with the given queue
    /// depths. Returns `None` when nothing is worth stealing.
    pub fn pick_victim(
        &self,
        thief: WorkerId,
        queue_depths: &[usize],
        rng: &mut Rng,
    ) -> Option<WorkerId> {
        let candidates: Vec<usize> = queue_depths
            .iter()
            .enumerate()
            .filter(|(w, d)| *w != thief.index() && **d > 0)
            .map(|(w, _)| w)
            .collect();
        if candidates.is_empty() {
            return None;
        }
        match self {
            StealPolicy::None => None,
            StealPolicy::RandomVictim => {
                Some(WorkerId(candidates[rng.range(0, candidates.len())] as u32))
            }
            StealPolicy::RichestVictim => candidates
                .into_iter()
                .max_by_key(|w| queue_depths[*w])
                .map(|w| WorkerId(w as u32)),
        }
    }
}

/// Pick the placement target for a ready task.
///
/// `loads` = queued+running per worker (`usize::MAX` marks a dead worker);
/// `holders` = workers already caching this task's inputs (empty slice
/// when unknown); `shard` = the task's partition-family annotation, if any.
pub fn place(
    policy: PlacementPolicy,
    task: TaskId,
    loads: &[usize],
    holders: &[WorkerId],
    shard: Option<&ShardInfo>,
    rr_counter: &mut usize,
) -> WorkerId {
    debug_assert!(!loads.is_empty());
    match policy {
        PlacementPolicy::RoundRobin => {
            let w = WorkerId((*rr_counter % loads.len()) as u32);
            *rr_counter += 1;
            w
        }
        PlacementPolicy::LeastLoaded => least_loaded(loads),
        PlacementPolicy::LocalityAware => prefer_holders(loads, holders),
        PlacementPolicy::ShardAffinity => match shard {
            // sibling leaves stripe across live workers: shard i of family
            // f always lands on the same worker, distinct i's spread out
            Some(s) if s.role == ShardRole::Leaf => {
                let live: Vec<usize> = loads
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| **l != usize::MAX)
                    .map(|(w, _)| w)
                    .collect();
                if live.is_empty() {
                    least_loaded(loads)
                } else {
                    WorkerId(live[(s.family as usize + s.index as usize) % live.len()] as u32)
                }
            }
            // combines (and everything else) chase their inputs
            _ => prefer_holders(loads, holders),
        },
    }
    .tap_trace(task)
}

/// Least-loaded among the *live* input holders, falling back to the
/// global least-loaded when the inputs' whereabouts are unknown — or when
/// every holder has died (a dead worker keeps its `locations` entries, so
/// holders must be re-checked against the `usize::MAX` dead marker).
fn prefer_holders(loads: &[usize], holders: &[WorkerId]) -> WorkerId {
    holders
        .iter()
        .copied()
        .filter(|w| loads[w.index()] != usize::MAX)
        .min_by_key(|w| loads[w.index()])
        .unwrap_or_else(|| least_loaded(loads))
}

fn least_loaded(loads: &[usize]) -> WorkerId {
    let (w, _) = loads
        .iter()
        .enumerate()
        .min_by_key(|(_, l)| **l)
        .expect("at least one worker");
    WorkerId(w as u32)
}

trait TapTrace {
    fn tap_trace(self, task: TaskId) -> Self;
}

impl TapTrace for WorkerId {
    fn tap_trace(self, task: TaskId) -> Self {
        crate::log_trace!("place", "{task} -> {self}");
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut ctr = 0;
        let loads = vec![0usize; 3];
        let picks: Vec<u32> = (0..6)
            .map(|i| place(PlacementPolicy::RoundRobin, TaskId(i), &loads, &[], None, &mut ctr).0)
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_minimum() {
        let mut ctr = 0;
        let w = place(
            PlacementPolicy::LeastLoaded,
            TaskId(0),
            &[3, 1, 2],
            &[],
            None,
            &mut ctr,
        );
        assert_eq!(w, WorkerId(1));
    }

    #[test]
    fn locality_prefers_holders_then_load() {
        let mut ctr = 0;
        let holders = [WorkerId(2), WorkerId(0)];
        let w = place(
            PlacementPolicy::LocalityAware,
            TaskId(0),
            &[5, 0, 1],
            &holders,
            None,
            &mut ctr,
        );
        assert_eq!(w, WorkerId(2)); // least-loaded among holders, not global min

        // no holders: falls back to global least-loaded
        let w = place(
            PlacementPolicy::LocalityAware,
            TaskId(0),
            &[5, 0, 1],
            &[],
            None,
            &mut ctr,
        );
        assert_eq!(w, WorkerId(1));
    }

    #[test]
    fn shard_affinity_spreads_siblings_and_follows_inputs() {
        let mut ctr = 0;
        let loads = [0usize, 0, 0, 0];
        let leaf = |index: u32| ShardInfo {
            family: 2,
            index,
            of: 4,
            role: ShardRole::Leaf,
        };
        // siblings of one family land on four distinct workers...
        let picks: std::collections::HashSet<WorkerId> = (0..4)
            .map(|i| {
                place(
                    PlacementPolicy::ShardAffinity,
                    TaskId(10 + i),
                    &loads,
                    &[],
                    Some(&leaf(i)),
                    &mut ctr,
                )
            })
            .collect();
        assert_eq!(picks.len(), 4);
        // ...and the mapping is deterministic
        let again = place(
            PlacementPolicy::ShardAffinity,
            TaskId(10),
            &loads,
            &[],
            Some(&leaf(0)),
            &mut ctr,
        );
        assert!(picks.contains(&again));

        // a dead worker (MAX load) is skipped by the stripe
        let loads_dead = [0usize, usize::MAX, 0, 0];
        for i in 0..8 {
            let w = place(
                PlacementPolicy::ShardAffinity,
                TaskId(20 + i),
                &loads_dead,
                &[],
                Some(&leaf(i)),
                &mut ctr,
            );
            assert_ne!(w, WorkerId(1), "shard {i} placed on the dead worker");
        }

        // a holder that has since died (MAX load) is never chosen — the
        // placement falls back to the live least-loaded worker
        let w = place(
            PlacementPolicy::ShardAffinity,
            TaskId(29),
            &[usize::MAX, 3, 1],
            &[WorkerId(0)],
            None,
            &mut ctr,
        );
        assert_eq!(w, WorkerId(2));
        let w = place(
            PlacementPolicy::LocalityAware,
            TaskId(29),
            &[usize::MAX, 3, 1],
            &[WorkerId(0)],
            None,
            &mut ctr,
        );
        assert_eq!(w, WorkerId(2));

        // combine nodes co-locate with their producers
        let combine = ShardInfo {
            family: 2,
            index: 0,
            of: 4,
            role: ShardRole::Combine,
        };
        let w = place(
            PlacementPolicy::ShardAffinity,
            TaskId(30),
            &[5, 0, 1, 9],
            &[WorkerId(3), WorkerId(2)],
            Some(&combine),
            &mut ctr,
        );
        assert_eq!(w, WorkerId(2)); // least-loaded holder

        // unannotated tasks behave like locality-aware
        let w = place(
            PlacementPolicy::ShardAffinity,
            TaskId(31),
            &[5, 0, 1],
            &[],
            None,
            &mut ctr,
        );
        assert_eq!(w, WorkerId(1));
    }

    #[test]
    fn steal_policies() {
        let mut rng = Rng::new(1);
        let depths = [0usize, 4, 2, 0];
        assert_eq!(
            StealPolicy::None.pick_victim(WorkerId(0), &depths, &mut rng),
            None
        );
        assert_eq!(
            StealPolicy::RichestVictim.pick_victim(WorkerId(0), &depths, &mut rng),
            Some(WorkerId(1))
        );
        for _ in 0..20 {
            let v = StealPolicy::RandomVictim
                .pick_victim(WorkerId(0), &depths, &mut rng)
                .unwrap();
            assert!(v == WorkerId(1) || v == WorkerId(2));
        }
        // thief's own queue is never a victim
        let depths = [9usize, 0, 0, 0];
        assert_eq!(
            StealPolicy::RandomVictim.pick_victim(WorkerId(0), &depths, &mut rng),
            None
        );
    }

    #[test]
    fn parse_names_roundtrip() {
        for p in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::LocalityAware,
            PlacementPolicy::ShardAffinity,
        ] {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        for s in [StealPolicy::None, StealPolicy::RandomVictim, StealPolicy::RichestVictim] {
            assert_eq!(StealPolicy::parse(s.name()), Some(s));
        }
        assert_eq!(PlacementPolicy::parse("bogus"), None);
    }
}
