"""L1 fused bias+activation kernel vs oracle, forward and backward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bias_act
from compile.kernels import ref


def _rand(shape, seed):
    return jax.random.normal(jax.random.key(seed), shape, jnp.float32)


@pytest.mark.parametrize("act", ["relu", "tanh", "none"])
@pytest.mark.parametrize("m,n", [(128, 256), (64, 64), (100, 30), (1, 16)])
def test_forward_matches_ref(act, m, n):
    x, b = _rand((m, n), 1), _rand((n,), 2)
    np.testing.assert_allclose(
        bias_act(x, b, act), ref.bias_act(x, b, act), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("act", ["relu", "tanh", "none"])
def test_backward_matches_ref(act):
    m, n = 64, 128
    x, b = _rand((m, n), 3), _rand((n,), 4)

    def f_k(x, b):
        return jnp.sum(bias_act(x, b, act) ** 2)

    def f_r(x, b):
        return jnp.sum(ref.bias_act(x, b, act) ** 2)

    gx_k, gb_k = jax.grad(f_k, argnums=(0, 1))(x, b)
    gx_r, gb_r = jax.grad(f_r, argnums=(0, 1))(x, b)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gb_k, gb_r, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 200),
    n=st.integers(1, 200),
    act=st.sampled_from(["relu", "tanh", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(m, n, act, seed):
    x, b = _rand((m, n), seed), _rand((n,), seed + 1)
    np.testing.assert_allclose(
        bias_act(x, b, act), ref.bias_act(x, b, act), rtol=1e-5, atol=1e-6
    )


def test_unknown_activation_raises():
    with pytest.raises(ValueError):
        bias_act(_rand((8, 8), 0), _rand((8,), 1), "gelu")
