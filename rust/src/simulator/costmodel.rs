//! Per-op cost model: measured where possible, analytic where not.
//!
//! Costs come from three layers, first hit wins:
//! 1. **measured** — mean ns per op key from `artifacts/costmodel.json`
//!    (written by `parhask calibrate`, which times the real PJRT
//!    executables on this machine);
//! 2. **intrinsic** — `Synthetic`/`IoAction` ops carry their own duration;
//! 3. **analytic** — `flops / flops_per_ns` from the task's estimate.
//!
//! The network model is bandwidth + per-message latency; defaults
//! approximate loopback TCP (measured by the micro bench).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::ir::task::{OpKind, TaskSpec};
use crate::util::json::Json;

/// Cost model for the simulator.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// op key -> mean ns (from calibration).
    measured: HashMap<String, u64>,
    /// Analytic fallback: effective compute rate (flops per ns).
    pub flops_per_ns: f64,
    /// Network bandwidth (bytes per ns). 1 GB/s = 1.074 bytes/ns.
    pub bytes_per_ns: f64,
    /// Host memory bandwidth for combine glue (bytes per ns). Prices the
    /// partition pass's slice/concat nodes per byte moved, so sharded vs
    /// unsharded tradeoffs stay predictable instead of glue being free.
    pub membw_bytes_per_ns: f64,
    /// Per-message latency (ns).
    pub latency_ns: u64,
    /// Leader dispatch overhead per assignment (ns).
    pub dispatch_ns: u64,
    /// Dispatch overhead for the 2nd..Nth leaf of a gang batch (ns):
    /// when the bucketed scheduler drains one shard family's bucket
    /// back-to-back, the leader amortizes argument prep and send setup
    /// across the batch, so only the first leaf pays the full
    /// `dispatch_ns`. Must be ≤ `dispatch_ns`; the greedy scheduler
    /// never batches and never uses this.
    pub gang_dispatch_ns: u64,
    /// Modeled warm-cache behaviour: probability in [0, 1] that a *pure*
    /// task is served from the leader's result cache instead of executing
    /// (Figure-2-style sweeps over warm-cache serving). 0 = cold cache.
    pub cache_hit_rate: f64,
    /// Leader-side cost of serving one cache hit (key hash + store probe).
    pub cache_serve_ns: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            measured: HashMap::new(),
            // ~2 GFLOP/s effective single-core XLA-CPU f32 matmul rate —
            // replaced by calibration whenever costmodel.json exists.
            flops_per_ns: 2.0,
            // ~2 GB/s loopback-ish
            bytes_per_ns: 2.0,
            // ~10 GB/s single-thread memcpy
            membw_bytes_per_ns: 10.0,
            latency_ns: 50_000,  // 50 µs per message
            dispatch_ns: 5_000,  // 5 µs leader overhead
            gang_dispatch_ns: 1_250, // amortized follow-up leaf in a gang batch
            cache_hit_rate: 0.0, // cold cache unless a sweep models warmth
            cache_serve_ns: 2_000,
        }
    }
}

impl CostModel {
    /// Cost key for an op (artifact name, host op label, etc.).
    pub fn key(op: &OpKind) -> String {
        op.label()
    }

    pub fn set_measured(&mut self, key: &str, ns: u64) {
        self.measured.insert(key.to_string(), ns);
    }

    pub fn measured(&self, key: &str) -> Option<u64> {
        self.measured.get(key).copied()
    }

    /// Simulated compute time of one task (ns).
    ///
    /// Partition-pass shard tasks never take the measured path: a
    /// calibrated per-op time describes the *whole* op, while a shard
    /// (which reuses the op verbatim) runs a 1/K row slice of it — so
    /// shards price analytically from their scaled estimates instead
    /// (`flops_per_ns` is itself calibrated, keeping the units honest).
    pub fn task_cost_ns(&self, spec: &TaskSpec) -> u64 {
        if spec.shard.is_none() {
            if let Some(ns) = self.measured.get(&Self::key(&spec.op)) {
                return (*ns).max(1);
            }
        }
        match &spec.op {
            OpKind::Synthetic { compute_us } => (*compute_us * 1_000).max(1),
            OpKind::IoAction { compute_us, .. } => (*compute_us * 1_000).max(1),
            // 1 µs of dispatch glue + per-byte memcpy of the inputs
            // (slice/concat shards carry real byte estimates; classic
            // zero-estimate combines price at the old flat 1 µs)
            OpKind::Combine(_) => {
                1_000 + (spec.est.bytes_in as f64 / self.membw_bytes_per_ns) as u64
            }
            _ => ((spec.est.flops as f64 / self.flops_per_ns) as u64).max(1),
        }
    }

    /// Simulated transfer time for `bytes` over the wire (ns).
    pub fn transfer_ns(&self, bytes: u64) -> u64 {
        self.latency_ns + (bytes as f64 / self.bytes_per_ns) as u64
    }

    // -- persistence ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut measured: Vec<(&str, Json)> = Vec::new();
        let mut keys: Vec<&String> = self.measured.keys().collect();
        keys.sort();
        for k in keys {
            measured.push((k.as_str(), Json::num(self.measured[k] as f64)));
        }
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("flops_per_ns", Json::num(self.flops_per_ns)),
            ("bytes_per_ns", Json::num(self.bytes_per_ns)),
            ("membw_bytes_per_ns", Json::num(self.membw_bytes_per_ns)),
            ("latency_ns", Json::num(self.latency_ns as f64)),
            ("dispatch_ns", Json::num(self.dispatch_ns as f64)),
            ("gang_dispatch_ns", Json::num(self.gang_dispatch_ns as f64)),
            ("cache_hit_rate", Json::num(self.cache_hit_rate)),
            ("cache_serve_ns", Json::num(self.cache_serve_ns as f64)),
            ("measured_ns", Json::Obj(
                measured
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v))
                    .collect(),
            )),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CostModel> {
        let mut cm = CostModel {
            flops_per_ns: j
                .get("flops_per_ns")
                .and_then(Json::as_f64)
                .unwrap_or(2.0),
            bytes_per_ns: j.get("bytes_per_ns").and_then(Json::as_f64).unwrap_or(2.0),
            membw_bytes_per_ns: j
                .get("membw_bytes_per_ns")
                .and_then(Json::as_f64)
                .unwrap_or(10.0),
            latency_ns: j.get("latency_ns").and_then(Json::as_u64).unwrap_or(50_000),
            dispatch_ns: j.get("dispatch_ns").and_then(Json::as_u64).unwrap_or(5_000),
            gang_dispatch_ns: j
                .get("gang_dispatch_ns")
                .and_then(Json::as_u64)
                .unwrap_or(1_250),
            cache_hit_rate: j
                .get("cache_hit_rate")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            cache_serve_ns: j
                .get("cache_serve_ns")
                .and_then(Json::as_u64)
                .unwrap_or(2_000),
            measured: HashMap::new(),
        };
        if let Some(Json::Obj(m)) = j.get("measured_ns") {
            for (k, v) in m {
                cm.measured
                    .insert(k.clone(), v.as_u64().context("bad measured ns")?);
            }
        }
        Ok(cm)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {path:?}"))
    }

    pub fn load(path: &Path) -> Result<CostModel> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Load `artifacts/costmodel.json` if present, else defaults.
    pub fn load_or_default(dir: &Path) -> CostModel {
        Self::load(&dir.join("costmodel.json")).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::task::{CostEst, TaskId};

    fn spec(op: OpKind, flops: u64) -> TaskSpec {
        TaskSpec {
            id: TaskId(0),
            op,
            args: vec![],
            n_outputs: 1,
            est: CostEst { flops, bytes_in: 0, bytes_out: 0 },
            label: "t".into(),
            shard: None,
        }
    }

    #[test]
    fn measured_beats_analytic() {
        let mut cm = CostModel::default();
        let s = spec(OpKind::Artifact { name: "matmul_256".into() }, 2 * 256u64.pow(3));
        let analytic = cm.task_cost_ns(&s);
        cm.set_measured("matmul_256", 123_456);
        assert_eq!(cm.task_cost_ns(&s), 123_456);
        assert_ne!(analytic, 123_456);
    }

    #[test]
    fn shard_tasks_ignore_whole_op_measurements() {
        use crate::ir::task::{ShardInfo, ShardRole};
        let mut cm = CostModel::default();
        cm.set_measured("matmul_256", 100_000_000);
        let mut s = spec(
            OpKind::Artifact { name: "matmul_256".into() },
            2 * 256u64.pow(3) / 4, // a 1/4 row shard's scaled estimate
        );
        s.shard = Some(ShardInfo { family: 0, index: 1, of: 4, role: ShardRole::Leaf });
        let cost = cm.task_cost_ns(&s);
        assert_ne!(cost, 100_000_000, "shard must not be priced as the whole op");
        assert_eq!(cost, ((s.est.flops as f64 / cm.flops_per_ns) as u64).max(1));
    }

    #[test]
    fn synthetic_uses_intrinsic_duration() {
        let cm = CostModel::default();
        assert_eq!(
            cm.task_cost_ns(&spec(OpKind::Synthetic { compute_us: 7 }, 999)),
            7_000
        );
    }

    #[test]
    fn transfer_has_latency_floor() {
        let cm = CostModel::default();
        assert!(cm.transfer_ns(0) >= cm.latency_ns);
        assert!(cm.transfer_ns(1 << 20) > cm.transfer_ns(1 << 10));
    }

    #[test]
    fn json_roundtrip() {
        let mut cm = CostModel::default();
        cm.set_measured("matmul_256", 42_000);
        cm.set_measured("matgen_64", 9_000);
        cm.flops_per_ns = 3.5;
        cm.membw_bytes_per_ns = 12.5;
        cm.cache_hit_rate = 0.25;
        cm.cache_serve_ns = 3_000;
        cm.gang_dispatch_ns = 900;
        let j = cm.to_json();
        let back = CostModel::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.measured("matmul_256"), Some(42_000));
        assert_eq!(back.flops_per_ns, 3.5);
        assert_eq!(back.membw_bytes_per_ns, 12.5);
        assert_eq!(back.cache_hit_rate, 0.25);
        assert_eq!(back.cache_serve_ns, 3_000);
        assert_eq!(back.gang_dispatch_ns, 900);
    }

    #[test]
    fn gang_dispatch_defaults_cheaper_and_survives_old_json() {
        let cm = CostModel::default();
        assert!(cm.gang_dispatch_ns < cm.dispatch_ns);
        // pre-gang snapshots (no gang_dispatch_ns key) still load
        let old = Json::parse(r#"{"version":1,"dispatch_ns":5000}"#).unwrap();
        let back = CostModel::from_json(&old).unwrap();
        assert_eq!(back.gang_dispatch_ns, 1_250);
    }

    #[test]
    fn combine_cost_scales_with_input_bytes() {
        let cm = CostModel::default();
        let cheap = spec(OpKind::Combine(crate::ir::task::CombineKind::AddScalars), 0);
        assert_eq!(cm.task_cost_ns(&cheap), 1_000, "zero-estimate glue keeps the flat price");
        let mut big = spec(OpKind::Combine(crate::ir::task::CombineKind::Concat), 0);
        big.est.bytes_in = 1 << 20;
        assert!(cm.task_cost_ns(&big) > 100_000, "a 1 MiB concat is not free");
    }
}
