//! Cluster assembly: in-proc clusters (the paper's simulated-workers mode),
//! the elastic churn harness, and real TCP clusters (`parhask worker`
//! processes).

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cache::ResultCache;
use crate::fault::{FaultPlan, WorkerFaults};
use crate::ir::TaskProgram;
use crate::scheduler::trace::RunResult;
use crate::scheduler::WorkerId;
use crate::tasks::Executor;
use crate::log_info;

use super::leader::{ClusterConfig, Leader};
use super::transport::{inproc_pair, tcp_split, MsgReceiver, MsgSender};
use super::worker::Worker;

/// Worker-side lease-renewal interval for a given leader lease: renew
/// well inside the lease so an idle-but-healthy worker is never expired.
fn lease_heartbeat(cfg: &ClusterConfig) -> Option<Duration> {
    if cfg.lease.is_zero() {
        None
    } else {
        Some((cfg.lease / 4).max(Duration::from_millis(1)))
    }
}

/// Run `program` on an in-process cluster of `n_workers` worker threads
/// exchanging fully-serialized messages — the paper's Cloud-Haskell-style
/// "simulated distributed" setup.
///
/// `faults[i]` (if provided) injects failures into worker `i`.
pub fn run_cluster_inproc(
    program: &TaskProgram,
    executor: Arc<dyn Executor>,
    n_workers: usize,
    cfg: ClusterConfig,
    faults: Option<Vec<WorkerFaults>>,
) -> Result<RunResult> {
    run_cluster_inproc_cached(program, executor, n_workers, cfg, faults, None)
}

/// [`run_cluster_inproc`] with an optional purity-aware result cache: the
/// leader short-circuits dispatch of content hits and deduplicates
/// identical in-flight tasks across workers.
pub fn run_cluster_inproc_cached(
    program: &TaskProgram,
    executor: Arc<dyn Executor>,
    n_workers: usize,
    cfg: ClusterConfig,
    faults: Option<Vec<WorkerFaults>>,
    cache: Option<Arc<ResultCache>>,
) -> Result<RunResult> {
    anyhow::ensure!(n_workers >= 1, "need at least one worker");
    let hb = lease_heartbeat(&cfg);
    let mut links: Vec<(Box<dyn MsgSender>, Box<dyn MsgReceiver>)> = Vec::new();
    let mut worker_handles = Vec::new();
    for i in 0..n_workers {
        let ((l_tx, l_rx), (w_tx, w_rx)) = inproc_pair();
        links.push((Box::new(l_tx), Box::new(l_rx)));
        let ex = Arc::clone(&executor);
        let fault = faults
            .as_ref()
            .and_then(|f| f.get(i).copied())
            .unwrap_or_default();
        worker_handles.push(
            std::thread::Builder::new()
                .name(format!("worker-{i}"))
                .spawn(move || {
                    let mut w = Worker::new(WorkerId(i as u32), w_tx, w_rx, ex).with_fault(fault);
                    if let Some(hb) = hb {
                        w = w.with_heartbeat(hb);
                    }
                    if let Err(e) = w.run() {
                        crate::log_warn!("worker", "w{i} error: {e:#}");
                    }
                })
                .context("spawning worker thread")?,
        );
    }
    let leader = Leader::new(program.clone(), links, cfg).with_cache(cache);
    let result = leader.run();
    for h in worker_handles {
        let _ = h.join();
    }
    result
}

/// Run `program` on an *elastic* in-process cluster driven by a
/// deterministic [`FaultPlan`]: `plan.initial_workers` threads start up
/// front, one more joins at each `plan.joins` commit step, and every
/// worker misbehaves exactly as `plan.faults` dictates (deaths, mutes,
/// straggler slowdowns). `plan.kill_leader_at_step` aborts the leader
/// mid-run to exercise ledger resume (`cfg.ledger_path`).
///
/// The same plan drives [`crate::simulator`]'s churn mode, which is what
/// lets tests cross-check a real churning run against its simulation.
pub fn run_cluster_churn(
    program: &TaskProgram,
    executor: Arc<dyn Executor>,
    mut cfg: ClusterConfig,
    plan: &FaultPlan,
    cache: Option<Arc<ResultCache>>,
) -> Result<RunResult> {
    anyhow::ensure!(
        plan.initial_workers >= 1,
        "churn plan needs at least one initial worker"
    );
    cfg.kill_at_step = cfg.kill_at_step.or(plan.kill_leader_at_step);
    let hb = lease_heartbeat(&cfg);
    let handles: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
        Arc::new(Mutex::new(Vec::new()));
    let faults: Vec<WorkerFaults> =
        (0..plan.total_workers()).map(|i| plan.worker(i)).collect();

    let mut spawn_worker = {
        let handles = Arc::clone(&handles);
        let executor = Arc::clone(&executor);
        move |id: WorkerId| -> Result<(Box<dyn MsgSender>, Box<dyn MsgReceiver>)> {
            let ((l_tx, l_rx), (w_tx, w_rx)) = inproc_pair();
            let ex = Arc::clone(&executor);
            let fault = faults.get(id.index()).copied().unwrap_or_default();
            let h = std::thread::Builder::new()
                .name(format!("worker-{id}"))
                .spawn(move || {
                    let mut w = Worker::new(id, w_tx, w_rx, ex).with_fault(fault);
                    if let Some(hb) = hb {
                        w = w.with_heartbeat(hb);
                    }
                    if let Err(e) = w.run() {
                        crate::log_warn!("worker", "{id} error: {e:#}");
                    }
                })
                .context("spawning worker thread")?;
            handles.lock().unwrap().push(h);
            Ok((
                Box::new(l_tx) as Box<dyn MsgSender>,
                Box::new(l_rx) as Box<dyn MsgReceiver>,
            ))
        }
    };

    let mut links = Vec::new();
    for i in 0..plan.initial_workers {
        links.push(spawn_worker(WorkerId(i as u32))?);
    }
    let leader = Leader::new(program.clone(), links, cfg)
        .with_cache(cache)
        .with_spawner(Box::new(spawn_worker), plan.joins.clone());
    let result = leader.run();
    // leader (and its sender halves) dropped by run(): every worker —
    // joined, muted, or idle — sees the channel close and exits
    let hs: Vec<_> = std::mem::take(&mut *handles.lock().unwrap());
    for h in hs {
        let _ = h.join();
    }
    result
}

/// Serve one worker over TCP: connect to the leader at `leader_addr`,
/// announce with `id`, execute until shutdown. This is the body of the
/// `parhask worker` subcommand.
pub fn serve_worker(
    leader_addr: &str,
    id: WorkerId,
    executor: Arc<dyn Executor>,
    fault: WorkerFaults,
) -> Result<()> {
    let stream = TcpStream::connect(leader_addr)
        .with_context(|| format!("connecting to leader at {leader_addr}"))?;
    let (tx, rx) = tcp_split(stream)?;
    log_info!("worker", "{id} connected to {leader_addr}");
    Worker::new(id, tx, rx, executor).with_fault(fault).run()
}

/// Run a TCP cluster: listen on `bind`, wait for `n_workers` connections,
/// then drive the program. Workers are external processes
/// (`parhask worker --leader <addr>`).
pub fn run_cluster_tcp<A: ToSocketAddrs>(
    program: &TaskProgram,
    bind: A,
    n_workers: usize,
    cfg: ClusterConfig,
) -> Result<RunResult> {
    run_cluster_tcp_cached(program, bind, n_workers, cfg, None)
}

/// [`run_cluster_tcp`] with an optional leader-side result cache.
pub fn run_cluster_tcp_cached<A: ToSocketAddrs>(
    program: &TaskProgram,
    bind: A,
    n_workers: usize,
    cfg: ClusterConfig,
    cache: Option<Arc<ResultCache>>,
) -> Result<RunResult> {
    let listener = TcpListener::bind(bind).context("binding leader socket")?;
    log_info!(
        "leader",
        "listening on {} for {n_workers} workers",
        listener.local_addr()?
    );
    let mut links: Vec<(Box<dyn MsgSender>, Box<dyn MsgReceiver>)> = Vec::new();
    for _ in 0..n_workers {
        let (stream, peer) = listener.accept().context("accepting worker")?;
        log_info!("leader", "worker connected from {peer}");
        let (tx, rx) = tcp_split(stream)?;
        links.push((Box::new(tx), Box::new(rx)));
    }
    Leader::new(program.clone(), links, cfg).with_cache(cache).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::task::{ArgRef, CombineKind, CostEst, OpKind};
    use crate::ir::ProgramBuilder;
    use crate::tasks::{HostExecutor, SyntheticExecutor};

    fn matrix_program(rounds: usize, n: usize) -> TaskProgram {
        let mut b = ProgramBuilder::new();
        let mut sums = Vec::new();
        for r in 0..rounds {
            let g1 = b.push(
                OpKind::HostMatGen { n },
                vec![ArgRef::const_i32(2 * r as i32)],
                1,
                CostEst { flops: 8 * (n * n) as u64, bytes_in: 4, bytes_out: (4 * n * n) as u64 },
                format!("a{r}"),
            );
            let g2 = b.push(
                OpKind::HostMatGen { n },
                vec![ArgRef::const_i32(2 * r as i32 + 1)],
                1,
                CostEst { flops: 8 * (n * n) as u64, bytes_in: 4, bytes_out: (4 * n * n) as u64 },
                format!("b{r}"),
            );
            let mm = b.push(
                OpKind::HostMatMul,
                vec![ArgRef::out(g1, 0), ArgRef::out(g2, 0)],
                1,
                CostEst { flops: 2 * (n * n * n) as u64, bytes_in: (8 * n * n) as u64, bytes_out: (4 * n * n) as u64 },
                format!("c{r}"),
            );
            let s = b.push(
                OpKind::HostMatSum,
                vec![ArgRef::out(mm, 0)],
                1,
                CostEst { flops: 2 * (n * n) as u64, bytes_in: (4 * n * n) as u64, bytes_out: 4 },
                format!("s{r}"),
            );
            sums.push(ArgRef::out(s, 0));
        }
        let total = b.push(
            OpKind::Combine(CombineKind::AddScalars),
            sums,
            1,
            CostEst::ZERO,
            "total",
        );
        b.mark_output(ArgRef::out(total, 0));
        b.build().unwrap()
    }

    fn expected_total(rounds: usize, n: usize) -> f32 {
        let mut acc = 0.0f64;
        for r in 0..rounds {
            let a = crate::tensor::Tensor::uniform(vec![n, n], 2 * r as u64);
            let b = crate::tensor::Tensor::uniform(vec![n, n], 2 * r as u64 + 1);
            acc += a.matmul(&b).unwrap().sumsq().unwrap() as f64;
        }
        acc as f32
    }

    #[test]
    fn inproc_cluster_correct_results() {
        let p = matrix_program(4, 16);
        let r = run_cluster_inproc(
            &p,
            Arc::new(HostExecutor),
            3,
            ClusterConfig::default(),
            None,
        )
        .unwrap();
        r.trace.validate(&p).unwrap();
        let got = r.outputs[0].as_tensor().unwrap().scalar().unwrap();
        let want = expected_total(4, 16);
        assert!((got - want).abs() / want < 1e-4, "{got} vs {want}");
        assert!(r.trace.bytes_transferred > 0);
    }

    #[test]
    fn single_worker_cluster_works() {
        let p = matrix_program(2, 8);
        let r = run_cluster_inproc(
            &p,
            Arc::new(HostExecutor),
            1,
            ClusterConfig::default(),
            None,
        )
        .unwrap();
        r.trace.validate(&p).unwrap();
    }

    #[test]
    fn all_placement_policies_complete() {
        use crate::scheduler::PlacementPolicy;
        let p = matrix_program(3, 8);
        for placement in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::LocalityAware,
        ] {
            let cfg = ClusterConfig {
                placement,
                ..Default::default()
            };
            let r =
                run_cluster_inproc(&p, Arc::new(HostExecutor), 2, cfg, None).unwrap();
            r.trace.validate(&p).unwrap();
            let got = r.outputs[0].as_tensor().unwrap().scalar().unwrap();
            let want = expected_total(3, 8);
            assert!((got - want).abs() / want < 1e-4, "{placement:?}");
        }
    }

    #[test]
    fn worker_death_recovers_via_reexecution() {
        let p = matrix_program(6, 8);
        let cfg = ClusterConfig {
            max_failures: 1,
            heartbeat: std::time::Duration::from_millis(50),
            ..Default::default()
        };
        // worker 0 dies after 2 tasks
        let faults = vec![
            WorkerFaults::dies_after(2),
            WorkerFaults::default(),
            WorkerFaults::default(),
        ];
        let r = run_cluster_inproc(&p, Arc::new(HostExecutor), 3, cfg, Some(faults)).unwrap();
        let got = r.outputs[0].as_tensor().unwrap().scalar().unwrap();
        let want = expected_total(6, 8);
        assert!((got - want).abs() / want < 1e-4, "{got} vs {want}");
        // note: trace may contain a duplicate event for a task that
        // completed just as its worker died; validate() is for exact runs.
    }

    #[test]
    fn elastic_join_plan_completes() {
        use crate::scheduler::trace::LeaseKind;
        let p = matrix_program(5, 8);
        // one worker at startup, two more join at commit steps 2 and 4
        let plan = FaultPlan {
            initial_workers: 1,
            joins: vec![2, 4],
            faults: vec![WorkerFaults::default(); 3],
            kill_leader_at_step: None,
        };
        let cfg = ClusterConfig {
            lease: Duration::from_millis(500),
            max_failures: 3,
            ..Default::default()
        };
        let r = run_cluster_churn(&p, Arc::new(HostExecutor), cfg, &plan, None).unwrap();
        r.trace.validate(&p).unwrap();
        let got = r.outputs[0].as_tensor().unwrap().scalar().unwrap();
        let want = expected_total(5, 8);
        assert!((got - want).abs() / want < 1e-4, "{got} vs {want}");
        let grants = r
            .trace
            .leases
            .iter()
            .filter(|l| l.kind == LeaseKind::Granted)
            .count();
        assert_eq!(grants, 3, "every member (joiners included) got a lease");
    }

    #[test]
    fn failure_budget_exhaustion_errors() {
        let p = matrix_program(6, 8);
        let cfg = ClusterConfig {
            max_failures: 0,
            heartbeat: std::time::Duration::from_millis(50),
            ..Default::default()
        };
        let faults = vec![WorkerFaults::dies_after(1), WorkerFaults::default()];
        let err =
            run_cluster_inproc(&p, Arc::new(HostExecutor), 2, cfg, Some(faults)).unwrap_err();
        assert!(format!("{err:#}").contains("failure budget"), "{err:#}");
    }

    #[test]
    fn warm_cache_cluster_run_executes_nothing_and_agrees() {
        let p = matrix_program(3, 8);
        let cache = ResultCache::new_enabled();
        let r1 = run_cluster_inproc_cached(
            &p,
            Arc::new(HostExecutor),
            2,
            ClusterConfig::default(),
            None,
            Some(Arc::clone(&cache)),
        )
        .unwrap();
        r1.trace.validate(&p).unwrap();
        let r2 = run_cluster_inproc_cached(
            &p,
            Arc::new(HostExecutor),
            2,
            ClusterConfig::default(),
            None,
            Some(cache),
        )
        .unwrap();
        r2.trace.validate(&p).unwrap();
        assert_eq!(r1.outputs, r2.outputs, "purity ⇒ bit-identical");
        assert_eq!(r2.trace.executed_tasks(), 0, "leader served the whole run");
        assert_eq!(r2.trace.cache_hits as usize, p.len());
        // only control traffic (shutdown frames) moves on a warm run
        assert!(r2.trace.bytes_transferred < 64, "{}", r2.trace.bytes_transferred);
    }

    #[test]
    fn leader_dedupes_identical_inflight_tasks() {
        // Two pairs of identical matgen tasks: the leader must execute one
        // of each pair and serve its twin from the in-flight dedup.
        let mut b = ProgramBuilder::new();
        for _ in 0..2 {
            for seed in [1, 2] {
                b.push(
                    OpKind::HostMatGen { n: 8 },
                    vec![ArgRef::const_i32(seed)],
                    1,
                    CostEst { flops: 64, bytes_in: 4, bytes_out: 256 },
                    format!("g{seed}"),
                );
            }
        }
        let p = b.build().unwrap();
        let cache = ResultCache::new_enabled();
        let r = run_cluster_inproc_cached(
            &p,
            Arc::new(HostExecutor),
            2,
            ClusterConfig::default(),
            None,
            Some(Arc::clone(&cache)),
        )
        .unwrap();
        r.trace.validate(&p).unwrap();
        assert_eq!(r.trace.cache_hits, 2, "one twin per pair served without executing");
        assert_eq!(r.trace.executed_tasks(), 2);
        // dedup serves count as hits in the store counters too
        assert_eq!(cache.stats().hits, r.trace.cache_hits);
    }

    #[test]
    fn synthetic_imbalanced_load_with_stealing() {
        use crate::scheduler::StealPolicy;
        // 1 huge + many small tasks; stealing should still complete fast
        let mut b = ProgramBuilder::new();
        for i in 0..12 {
            let us = if i == 0 { 20_000 } else { 500 };
            b.push(
                OpKind::Synthetic { compute_us: us },
                vec![],
                1,
                CostEst { flops: us, bytes_in: 0, bytes_out: 0 },
                format!("t{i}"),
            );
        }
        let p = b.build().unwrap();
        for steal in [StealPolicy::None, StealPolicy::RandomVictim, StealPolicy::RichestVictim] {
            let cfg = ClusterConfig {
                steal,
                pipeline_depth: 6,
                ..Default::default()
            };
            let r = run_cluster_inproc(&p, Arc::new(SyntheticExecutor), 2, cfg, None).unwrap();
            r.trace.validate(&p).unwrap();
        }
    }

    #[test]
    fn tcp_cluster_end_to_end() {
        let p = matrix_program(3, 8);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener); // free the port; race is fine for a test
        let addr_s = addr.to_string();

        let worker_threads: Vec<_> = (0..2)
            .map(|i| {
                let addr_s = addr_s.clone();
                std::thread::spawn(move || {
                    // retry until leader listens
                    for _ in 0..100 {
                        match serve_worker(
                            &addr_s,
                            WorkerId(i),
                            Arc::new(HostExecutor),
                            WorkerFaults::default(),
                        ) {
                            Ok(()) => return,
                            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
                        }
                    }
                    panic!("worker never connected");
                })
            })
            .collect();

        let r = run_cluster_tcp(&p, addr, 2, ClusterConfig::default()).unwrap();
        r.trace.validate(&p).unwrap();
        let got = r.outputs[0].as_tensor().unwrap().scalar().unwrap();
        let want = expected_total(3, 8);
        assert!((got - want).abs() / want < 1e-4);
        for t in worker_threads {
            t.join().unwrap();
        }
    }
}
