"""AOT pipeline tests: lowering round-trips, manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ARTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_registry_covers_expected_families():
    reg = aot.build_registry()
    for n in model.MAT_SIZES:
        for fam in ("matgen", "matmul", "matsum", "matround"):
            assert f"{fam}_{n}" in reg
    for name in ("mlp_init", "mlp_grad", "mlp_apply", "mlp_datagen"):
        assert name in reg


def test_hlo_text_parseable_and_entry_named():
    reg = aot.build_registry()
    ent = reg["matmul_64"]
    text = aot.to_hlo_text(ent["fn"], ent["args"])
    assert "ENTRY" in text and "f32[64,64]" in text
    # return_tuple=True → entry layout returns a 1-tuple
    assert "->(f32[64,64]" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTDIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_files_and_shapes():
    with open(os.path.join(ARTDIR, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    names = set()
    for ent in man["artifacts"]:
        names.add(ent["name"])
        path = os.path.join(ARTDIR, ent["file"])
        assert os.path.exists(path), ent["file"]
        assert ent["flops"] > 0 and ent["bytes_in"] >= 4 and ent["bytes_out"] >= 4
        for d in ent["inputs"] + ent["outputs"]:
            assert d["dtype"] in ("f32", "i32")
    assert "matmul_256" in names and "mlp_grad" in names


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTDIR, "manifest.json")),
    reason="artifacts not built",
)
def test_kernel_report_structural_sanity():
    with open(os.path.join(ARTDIR, "manifest.json")) as f:
        man = json.load(f)
    rep = {r["kernel"]: r for r in man["kernel_report"]}
    r256 = rep["matmul_256"]
    assert r256["block"] == [128, 128, 128]
    assert r256["vmem_bytes"] < 16 * 1024 * 1024
    assert r256["mxu_utilization"] == 1.0


def test_lowered_matgen_executes_like_eager():
    """Execute the lowered HLO via jax's own CPU client and compare."""
    reg = aot.build_registry()
    ent = reg["matgen_64"]
    lowered = jax.jit(ent["fn"]).lower(*ent["args"])
    compiled = lowered.compile()
    (out,) = compiled(jnp.int32(5))
    (ref_out,) = model.matgen(5, 64)
    np.testing.assert_allclose(out, ref_out, rtol=1e-6)
