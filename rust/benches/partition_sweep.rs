//! Partition sweep: makespan and bytes moved as the shard count K grows.
//!
//! Two views of the same question ("when does intra-op sharding win?"):
//!
//! * **simulator** — one matmul-dominated round at several sizes, swept
//!   over K on 8 workers: shows the U-curve where glue + transfers
//!   eventually eat the compute win, with the bucketed (default) and
//!   greedy schedulers side by side — gang-draining a shard family
//!   amortizes dispatch, so bucketed wins on every partitioned point;
//! * **real in-proc cluster** — the host-op matrix workload at a modest
//!   size, confirming the simulator's ordering on actual execution.
//!
//! ```sh
//! cargo bench --bench partition_sweep
//! ```

use std::sync::Arc;

use parhask::cluster::{run_cluster_inproc, ClusterConfig};
use parhask::metrics::Table;
use parhask::partition::{partition_program, PartitionConfig};
use parhask::scheduler::{PlacementPolicy, SchedulerKind};
use parhask::simulator::{simulate, CostModel, SimConfig};
use parhask::tasks::HostExecutor;
use parhask::workload::{matmul_round_program, matrix_program};

const SWEEP_K: [usize; 5] = [1, 2, 4, 8, 16];

fn main() -> anyhow::Result<()> {
    sim_sweep()?;
    cluster_sweep()?;
    Ok(())
}

fn sim_sweep() -> anyhow::Result<()> {
    let cm = CostModel::default();
    let mut table = Table::new(
        "simulated matmul round on 8 workers (shard-affinity placement)",
        &["size", "K", "tasks", "bucketed ms", "greedy ms", "bytes moved", "speedup"],
    );
    for n in [256usize, 512, 1024] {
        let base = matmul_round_program(n);
        let mut base_ms = 0.0f64;
        for k in SWEEP_K {
            let program = if k <= 1 {
                base.clone()
            } else {
                partition_program(&base, &PartitionConfig::aggressive(k))?.program
            };
            let mut cfg = SimConfig::cluster(8);
            cfg.placement = PlacementPolicy::ShardAffinity;
            let r = simulate(&program, &cm, &cfg)?;
            cfg.scheduler = SchedulerKind::Greedy;
            let rg = simulate(&program, &cm, &cfg)?;
            let ms = r.makespan_ns as f64 / 1e6;
            if k <= 1 {
                base_ms = ms;
            }
            table.row(vec![
                n.to_string(),
                k.to_string(),
                program.len().to_string(),
                format!("{ms:.3}"),
                format!("{:.3}", rg.makespan_ns as f64 / 1e6),
                r.bytes_transferred.to_string(),
                format!("{:.2}x", base_ms / ms),
            ]);
        }
    }
    println!("{}", table.render());
    println!("(bucketed gang-drains each shard family, so consecutive leaf");
    println!(" dispatches of one family pay the discounted dispatch cost)");
    Ok(())
}

fn cluster_sweep() -> anyhow::Result<()> {
    let mut table = Table::new(
        "real in-proc cluster, 4 workers, 4 rounds @ 96x96 host ops",
        &["K", "tasks", "wall ms", "arg bytes shipped", "arg bytes saved"],
    );
    let base = matrix_program(4, 96, false, None);
    for k in SWEEP_K {
        let program = if k <= 1 {
            base.clone()
        } else {
            partition_program(&base, &PartitionConfig::aggressive(k))?.program
        };
        let cfg = ClusterConfig {
            placement: PlacementPolicy::ShardAffinity,
            ..ClusterConfig::default()
        };
        let r = run_cluster_inproc(&program, Arc::new(HostExecutor), 4, cfg, None)?;
        table.row(vec![
            k.to_string(),
            program.len().to_string(),
            format!("{:.3}", r.trace.wall_ns as f64 / 1e6),
            r.trace.arg_bytes_shipped.to_string(),
            r.trace.arg_bytes_saved.to_string(),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
