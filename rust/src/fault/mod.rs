//! Deterministic fault-injection harness.
//!
//! A [`FaultPlan`] is a *seeded, pre-computed* schedule of cluster
//! misbehaviour — worker joins, deaths, mutes (a worker that keeps
//! running but stops talking, which is what a network partition looks
//! like from the leader), straggler slowdowns, and a leader
//! kill-at-step. The same plan drives the discrete-event simulator,
//! the real in-proc cluster, and unit tests, so every churn scenario
//! is reproducible from a single `u64` seed: no sleeps, no wall-clock
//! races, no flaky tests.
//!
//! Schedules are expressed in *commit steps* (the leader's count of
//! committed task results), not wall time — the one clock that is
//! identical between the simulator and a real run.

use crate::util::rng::Rng;

/// Per-worker fault behaviour. `Default` is a healthy worker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerFaults {
    /// Exit silently (thread death, no `Bye`) after completing this
    /// many tasks.
    pub die_after_tasks: Option<usize>,
    /// Stop sending *anything* (results, heartbeats) after completing
    /// this many tasks, but keep the process alive: the leader can only
    /// find out through lease expiry.
    pub mute_after_tasks: Option<usize>,
    /// Straggler factor: execution takes `slow_factor` times as long.
    /// `1.0` is a healthy worker; values below 1 are clamped to 1.
    pub slow_factor: f64,
}

impl Default for WorkerFaults {
    fn default() -> Self {
        WorkerFaults {
            die_after_tasks: None,
            mute_after_tasks: None,
            slow_factor: 1.0,
        }
    }
}

impl WorkerFaults {
    /// Shorthand for the classic single-fault case: a worker that dies
    /// after completing `k` tasks.
    pub fn dies_after(k: usize) -> Self {
        WorkerFaults {
            die_after_tasks: Some(k),
            ..Default::default()
        }
    }

    /// Completion count after which the worker stops contributing
    /// (dies or mutes), whichever comes first.
    pub fn stops_after(&self) -> Option<usize> {
        match (self.die_after_tasks, self.mute_after_tasks) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

/// Rates for [`FaultPlan::poisson`]. All schedules derive from these
/// plus a seed, so a plan is fully described by `(seed, rates)`.
#[derive(Clone, Copy, Debug)]
pub struct PoissonRates {
    /// Expected worker joins per commit step (Poisson arrivals).
    pub join_rate: f64,
    /// Mean number of tasks a mortal worker completes before dying
    /// (exponential lifetime). `0.0` disables deaths.
    pub mean_lifetime_tasks: f64,
    /// Fraction of workers that are immortal regardless of
    /// `mean_lifetime_tasks` — a floor that guarantees forward
    /// progress under arbitrarily vicious churn.
    pub immortal_fraction: f64,
    /// Fraction of workers that are stragglers.
    pub straggler_fraction: f64,
    /// Slowdown applied to stragglers.
    pub straggler_factor: f64,
}

impl Default for PoissonRates {
    fn default() -> Self {
        PoissonRates {
            join_rate: 0.02,
            mean_lifetime_tasks: 40.0,
            immortal_fraction: 0.1,
            straggler_fraction: 0.05,
            straggler_factor: 4.0,
        }
    }
}

/// A deterministic cluster-level fault schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Workers present at startup (ids `0..initial_workers`).
    pub initial_workers: usize,
    /// Commit-step thresholds at which one new worker joins, sorted
    /// ascending. Entry `i` corresponds to worker id
    /// `initial_workers + i`.
    pub joins: Vec<u64>,
    /// Per-worker fault behaviour, indexed by worker id (initial
    /// workers first, then joiners). Missing entries mean healthy.
    pub faults: Vec<WorkerFaults>,
    /// Kill the leader after it commits this many task results
    /// (exercises the execution-ledger resume path).
    pub kill_leader_at_step: Option<u64>,
}

impl FaultPlan {
    /// A faultless fixed-size cluster — the degenerate plan every
    /// pre-churn code path is equivalent to.
    pub fn fixed(n_workers: usize) -> FaultPlan {
        FaultPlan {
            initial_workers: n_workers,
            ..Default::default()
        }
    }

    /// Total workers that will ever exist under this plan.
    pub fn total_workers(&self) -> usize {
        self.initial_workers + self.joins.len()
    }

    /// Fault behaviour for worker `i` (healthy when unspecified).
    pub fn worker(&self, i: usize) -> WorkerFaults {
        self.faults.get(i).copied().unwrap_or_default()
    }

    /// Sample a churn schedule: Poisson worker arrivals over
    /// `horizon_steps` commit steps, exponential lifetimes (in
    /// completed tasks) and straggler slowdowns for every worker.
    /// Identical `(seed, initial_workers, horizon_steps, rates)`
    /// always yields an identical plan.
    pub fn poisson(
        seed: u64,
        initial_workers: usize,
        horizon_steps: u64,
        rates: &PoissonRates,
    ) -> FaultPlan {
        let mut join_rng = Rng::new(seed).split(0x4A01);
        let mut fate_rng = Rng::new(seed).split(0xFA7E);

        let mut joins = Vec::new();
        if rates.join_rate > 0.0 {
            // Exponential inter-arrival times give a Poisson process.
            let mut t = 0.0f64;
            loop {
                let u = join_rng.f64();
                t += -(1.0 - u).ln() / rates.join_rate;
                if t >= horizon_steps as f64 {
                    break;
                }
                joins.push(t as u64);
            }
        }

        let total = initial_workers + joins.len();
        let mut faults = Vec::with_capacity(total);
        for _ in 0..total {
            let mut f = WorkerFaults::default();
            let immortal = fate_rng.chance(rates.immortal_fraction);
            if !immortal && rates.mean_lifetime_tasks > 0.0 {
                let u = fate_rng.f64();
                let life = -(1.0 - u).ln() * rates.mean_lifetime_tasks;
                f.die_after_tasks = Some(1 + life as usize);
            }
            if fate_rng.chance(rates.straggler_fraction) {
                f.slow_factor = rates.straggler_factor.max(1.0);
            }
            faults.push(f);
        }

        FaultPlan {
            initial_workers,
            joins,
            faults,
            kill_leader_at_step: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_plan_is_deterministic() {
        let rates = PoissonRates::default();
        let a = FaultPlan::poisson(42, 8, 500, &rates);
        let b = FaultPlan::poisson(42, 8, 500, &rates);
        assert_eq!(a, b);
        let c = FaultPlan::poisson(43, 8, 500, &rates);
        assert_ne!(a, c, "different seeds should sample different plans");
    }

    #[test]
    fn poisson_plan_shape_is_consistent() {
        let rates = PoissonRates {
            join_rate: 0.1,
            mean_lifetime_tasks: 10.0,
            immortal_fraction: 0.2,
            straggler_fraction: 0.3,
            straggler_factor: 3.0,
        };
        let plan = FaultPlan::poisson(7, 4, 1000, &rates);
        assert_eq!(plan.initial_workers, 4);
        assert!(!plan.joins.is_empty(), "rate 0.1 over 1000 steps joins someone");
        assert!(plan.joins.windows(2).all(|w| w[0] <= w[1]), "joins sorted");
        assert!(plan.joins.iter().all(|j| *j < 1000));
        assert_eq!(plan.faults.len(), plan.total_workers());
        assert!(plan.faults.iter().any(|f| f.die_after_tasks.is_some()));
        assert!(plan.faults.iter().any(|f| f.die_after_tasks.is_none()));
        assert!(plan.faults.iter().any(|f| f.slow_factor > 1.0));
        assert!(plan
            .faults
            .iter()
            .all(|f| f.die_after_tasks.map_or(true, |k| k >= 1)));
    }

    #[test]
    fn zero_rates_mean_no_faults() {
        let rates = PoissonRates {
            join_rate: 0.0,
            mean_lifetime_tasks: 0.0,
            immortal_fraction: 0.0,
            straggler_fraction: 0.0,
            straggler_factor: 1.0,
        };
        let plan = FaultPlan::poisson(1, 3, 100, &rates);
        assert_eq!(plan.joins, Vec::<u64>::new());
        assert_eq!(plan.faults, vec![WorkerFaults::default(); 3]);
        assert_eq!(plan, {
            let mut fixed = FaultPlan::fixed(3);
            fixed.faults = vec![WorkerFaults::default(); 3];
            fixed
        });
    }

    #[test]
    fn stops_after_takes_the_earlier_fault() {
        let f = WorkerFaults {
            die_after_tasks: Some(5),
            mute_after_tasks: Some(3),
            slow_factor: 1.0,
        };
        assert_eq!(f.stops_after(), Some(3));
        assert_eq!(WorkerFaults::default().stops_after(), None);
        assert_eq!(WorkerFaults::dies_after(2).stops_after(), Some(2));
    }
}
