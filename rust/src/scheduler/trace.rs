//! Schedule traces: what ran where and when, with validation against the
//! program's dependency structure — the property the whole system must
//! preserve.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::ir::task::{TaskId, Value};
use crate::ir::TaskProgram;

use super::WorkerId;

/// One task execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    pub task: TaskId,
    pub worker: WorkerId,
    pub start_ns: u64,
    pub end_ns: u64,
}

/// A value eviction: `task`'s outputs were dropped from wherever they were
/// held (result cache tier or worker-resident store) at `at_ns`.
///
/// No engine evicts today — values live for the whole run — so current
/// traces carry an empty list. The field exists so the race auditor
/// (`analysis::race`) can prove the use-after-eviction property the planned
/// distributed cache tier and speculative re-execution (ROADMAP items 2–3)
/// must preserve: once they evict, they must record it here.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvictionEvent {
    pub task: TaskId,
    pub at_ns: u64,
}

/// One dispatch *attempt* of a task to a worker. A task may have several
/// attempts — after a lease expiry its work is requeued, and speculative
/// re-execution deliberately races a duplicate — but exactly one attempt
/// per task may have `won == true`: the one whose result the leader
/// committed (first-result-wins; purity makes the race free).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AttemptEvent {
    pub task: TaskId,
    pub worker: WorkerId,
    /// True for a speculative duplicate launched against a straggler,
    /// false for a primary (first or post-requeue) dispatch.
    pub speculative: bool,
    /// The leader committed this attempt's result.
    pub won: bool,
    pub at_ns: u64,
}

/// Membership lease transition for one worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeaseKind {
    /// Worker admitted to the cluster (startup or elastic join).
    Granted,
    /// Lease expired (silence or disconnect); the worker is dead to the
    /// leader from `at_ns` on.
    Expired,
}

/// One membership-lease event.
#[derive(Clone, Debug, PartialEq)]
pub struct LeaseEvent {
    pub worker: WorkerId,
    pub kind: LeaseKind,
    pub at_ns: u64,
    /// For `Expired`: in-flight tasks lost with the worker and requeued.
    /// Every re-executed task must appear in some expiry's `lost` list —
    /// that is the auditor's "re-execution only of lost work" property.
    pub lost: Vec<TaskId>,
}

/// Full schedule trace of one run.
#[derive(Clone, Debug, Default)]
pub struct ScheduleTrace {
    pub events: Vec<TraceEvent>,
    /// Bytes shipped worker↔leader (0 for shared-memory engines).
    pub bytes_transferred: u64,
    /// Wall-clock of the whole run (ns); ≥ max event end.
    pub wall_ns: u64,
    /// Tasks served from the result cache instead of executing. These have
    /// no [`TraceEvent`]; `events.len() + cached_tasks.len()` covers the
    /// whole program when the run completed.
    pub cached_tasks: Vec<TaskId>,
    /// Result-cache lookups that hit during this run (always equals
    /// `cached_tasks.len()`; the simulator's modeled warm cache counts
    /// here too).
    pub cache_hits: u64,
    /// Result-cache lookups that missed during this run.
    pub cache_misses: u64,
    /// Argument bytes the leader shipped inline to workers (cluster engine
    /// only; the leader's value-location table decides what must travel).
    pub arg_bytes_shipped: u64,
    /// Argument bytes saved by `Cached` references — the value already
    /// lived on the target worker, so locality placement turned a ship
    /// into a no-op.
    pub arg_bytes_saved: u64,
    /// Value evictions, if the executing tier dropped any results mid-run
    /// (empty on every current engine; see [`EvictionEvent`]).
    pub evictions: Vec<EvictionEvent>,
    /// Every dispatch attempt (primary, requeue, speculative) with its
    /// first-result-wins outcome. Empty on engines without churn.
    pub attempts: Vec<AttemptEvent>,
    /// Membership-lease grants and expiries, in leader observation order.
    pub leases: Vec<LeaseEvent>,
    /// Tasks served from the execution ledger on leader restart instead
    /// of executing. Like `cached_tasks`, these carry no [`TraceEvent`].
    pub resumed_tasks: Vec<TaskId>,
}

/// Outputs + trace of one engine run.
#[derive(Debug)]
pub struct RunResult {
    pub outputs: Vec<Value>,
    pub trace: ScheduleTrace,
}

impl ScheduleTrace {
    pub fn push(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    /// Record a result-cache hit: `task`'s outputs were served without
    /// executing it.
    pub fn record_cache_hit(&mut self, task: TaskId) {
        self.cached_tasks.push(task);
        self.cache_hits += 1;
    }

    /// Record a dispatch attempt (not yet won — see
    /// [`ScheduleTrace::mark_attempt_won`]).
    pub fn record_attempt(&mut self, task: TaskId, worker: WorkerId, speculative: bool, at_ns: u64) {
        self.attempts.push(AttemptEvent {
            task,
            worker,
            speculative,
            won: false,
            at_ns,
        });
    }

    /// Mark the latest attempt of `task` on `worker` as the committed one.
    pub fn mark_attempt_won(&mut self, task: TaskId, worker: WorkerId) {
        if let Some(a) = self
            .attempts
            .iter_mut()
            .rev()
            .find(|a| a.task == task && a.worker == worker)
        {
            a.won = true;
        }
    }

    /// Record a membership-lease transition.
    pub fn record_lease(&mut self, worker: WorkerId, kind: LeaseKind, at_ns: u64, lost: Vec<TaskId>) {
        self.leases.push(LeaseEvent {
            worker,
            kind,
            at_ns,
            lost,
        });
    }

    /// Record a task served from the execution ledger (leader resume).
    pub fn record_resumed(&mut self, task: TaskId) {
        self.resumed_tasks.push(task);
    }

    /// Tasks that actually executed (cache hits excluded).
    pub fn executed_tasks(&self) -> usize {
        self.events.len()
    }

    /// Absolute timestamp of the first dispatch-to-execution, if any —
    /// the serving plane's per-session "first task started" marker
    /// (cache-hit-only sessions have no events and return None).
    pub fn first_start_ns(&self) -> Option<u64> {
        self.events.iter().map(|e| e.start_ns).min()
    }

    /// Makespan: last end − first start.
    pub fn makespan_ns(&self) -> u64 {
        let start = self.events.iter().map(|e| e.start_ns).min().unwrap_or(0);
        let end = self.events.iter().map(|e| e.end_ns).max().unwrap_or(0);
        end.saturating_sub(start)
    }

    pub fn n_workers(&self) -> usize {
        self.events
            .iter()
            .map(|e| e.worker.index() + 1)
            .max()
            .unwrap_or(0)
    }

    /// Per-worker busy nanoseconds.
    pub fn busy_ns(&self) -> Vec<u64> {
        let mut busy = vec![0u64; self.n_workers()];
        for e in &self.events {
            busy[e.worker.index()] += e.end_ns - e.start_ns;
        }
        busy
    }

    /// Mean worker utilization over the makespan.
    pub fn utilization(&self) -> f64 {
        let span = self.makespan_ns();
        if span == 0 || self.events.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.busy_ns().iter().sum();
        busy as f64 / (span as f64 * self.n_workers() as f64)
    }

    /// Validate against a program:
    /// 1. every task either ran exactly once, was served from the result
    ///    cache, or was resumed from the execution ledger (never more
    ///    than one of these);
    /// 2. no executed task started before its *executed* dependencies
    ///    ended (allowing equal timestamps — the simulator is discrete;
    ///    cache-served and ledger-resumed dependencies have no execution
    ///    interval to order against);
    /// 3. no worker ran two tasks at overlapping times.
    pub fn validate(&self, program: &TaskProgram) -> Result<()> {
        let cached: std::collections::HashSet<TaskId> =
            self.cached_tasks.iter().copied().collect();
        if cached.len() != self.cached_tasks.len() {
            bail!("a task was served from cache more than once in one run");
        }
        let resumed: std::collections::HashSet<TaskId> =
            self.resumed_tasks.iter().copied().collect();
        if resumed.len() != self.resumed_tasks.len() {
            bail!("a task was resumed from the ledger more than once in one run");
        }
        if let Some(t) = cached.intersection(&resumed).next() {
            bail!("task {t} both cache-served and ledger-resumed");
        }
        // served tasks have results without an execution interval
        let served: std::collections::HashSet<TaskId> =
            cached.union(&resumed).copied().collect();
        let mut by_task: HashMap<TaskId, &TraceEvent> = HashMap::new();
        for e in &self.events {
            if by_task.insert(e.task, e).is_some() {
                bail!("task {} executed more than once", e.task);
            }
            if cached.contains(&e.task) {
                bail!("task {} both executed and served from cache", e.task);
            }
            if resumed.contains(&e.task) {
                bail!("task {} both executed and resumed from the ledger", e.task);
            }
            if e.end_ns < e.start_ns {
                bail!("task {} ends before it starts", e.task);
            }
        }
        for t in program.tasks() {
            if served.contains(&t.id) {
                continue;
            }
            let Some(ev) = by_task.get(&t.id) else {
                bail!("task {} never executed", t.id);
            };
            for d in t.deps() {
                if served.contains(&d) {
                    continue;
                }
                let dep_ev = by_task
                    .get(&d)
                    .ok_or_else(|| anyhow::anyhow!("dependency {d} of {} missing", t.id))?;
                if ev.start_ns < dep_ev.end_ns {
                    bail!(
                        "task {} started at {} before dependency {} finished at {}",
                        t.id,
                        ev.start_ns,
                        d,
                        dep_ev.end_ns
                    );
                }
            }
        }
        // per-worker serial execution
        let mut per_worker: HashMap<WorkerId, Vec<&TraceEvent>> = HashMap::new();
        for e in &self.events {
            per_worker.entry(e.worker).or_default().push(e);
        }
        for (w, mut evs) in per_worker {
            evs.sort_by_key(|e| e.start_ns);
            for pair in evs.windows(2) {
                if pair[1].start_ns < pair[0].end_ns {
                    bail!(
                        "worker {w} overlaps: {} [{}..{}] and {} [{}..{}]",
                        pair[0].task,
                        pair[0].start_ns,
                        pair[0].end_ns,
                        pair[1].task,
                        pair[1].start_ns,
                        pair[1].end_ns
                    );
                }
            }
        }
        Ok(())
    }

    /// ASCII Gantt chart (one row per worker, `width` columns).
    pub fn gantt(&self, width: usize) -> String {
        let span = self.makespan_ns().max(1);
        let t0 = self.events.iter().map(|e| e.start_ns).min().unwrap_or(0);
        let mut rows = vec![vec![b'.'; width]; self.n_workers()];
        for e in &self.events {
            let a = ((e.start_ns - t0) as u128 * width as u128 / span as u128) as usize;
            let b = (((e.end_ns - t0) as u128 * width as u128).div_ceil(span as u128) as usize)
                .min(width);
            let ch = b"0123456789abcdefghijklmnopqrstuvwxyz"[e.task.index() % 36];
            for c in &mut rows[e.worker.index()][a..b.max(a + 1).min(width)] {
                *c = ch;
            }
        }
        rows.iter()
            .enumerate()
            .map(|(i, r)| format!("w{i} |{}|", String::from_utf8_lossy(r)))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::task::OpKind;
    use crate::ir::ProgramBuilder;

    fn chain2() -> TaskProgram {
        let mut b = ProgramBuilder::new();
        let a = b.push_simple(OpKind::Synthetic { compute_us: 1 }, &[], "a");
        let _c = b.push_simple(OpKind::Synthetic { compute_us: 1 }, &[a], "c");
        b.build().unwrap()
    }

    fn ev(task: u32, worker: u32, s: u64, e: u64) -> TraceEvent {
        TraceEvent {
            task: TaskId(task),
            worker: WorkerId(worker),
            start_ns: s,
            end_ns: e,
        }
    }

    #[test]
    fn valid_trace_passes() {
        let p = chain2();
        let mut t = ScheduleTrace::default();
        t.push(ev(0, 0, 0, 10));
        t.push(ev(1, 1, 10, 25));
        t.validate(&p).unwrap();
        assert_eq!(t.makespan_ns(), 25);
        assert_eq!(t.busy_ns(), vec![10, 15]);
    }

    #[test]
    fn dependency_violation_caught() {
        let p = chain2();
        let mut t = ScheduleTrace::default();
        t.push(ev(0, 0, 0, 10));
        t.push(ev(1, 1, 5, 25)); // starts before dep ends
        assert!(t.validate(&p).is_err());
    }

    #[test]
    fn missing_and_duplicate_tasks_caught() {
        let p = chain2();
        let mut t = ScheduleTrace::default();
        t.push(ev(0, 0, 0, 10));
        assert!(t.validate(&p).is_err()); // task 1 missing

        let mut t = ScheduleTrace::default();
        t.push(ev(0, 0, 0, 10));
        t.push(ev(0, 0, 10, 20));
        assert!(t.validate(&p).is_err()); // duplicate
    }

    #[test]
    fn worker_overlap_caught() {
        let mut b = ProgramBuilder::new();
        b.push_simple(OpKind::Synthetic { compute_us: 1 }, &[], "a");
        b.push_simple(OpKind::Synthetic { compute_us: 1 }, &[], "b");
        let p = b.build().unwrap();
        let mut t = ScheduleTrace::default();
        t.push(ev(0, 0, 0, 10));
        t.push(ev(1, 0, 5, 15)); // same worker, overlapping
        assert!(t.validate(&p).is_err());
    }

    #[test]
    fn cache_served_tasks_validate() {
        let p = chain2();
        let mut t = ScheduleTrace::default();
        t.record_cache_hit(TaskId(0));
        t.push(ev(1, 0, 5, 10));
        t.validate(&p).unwrap();
        assert_eq!(t.executed_tasks(), 1);
        assert_eq!(t.cache_hits, 1);

        // a fully-cached run is also valid
        let mut t = ScheduleTrace::default();
        t.record_cache_hit(TaskId(0));
        t.record_cache_hit(TaskId(1));
        t.validate(&p).unwrap();
        assert_eq!(t.executed_tasks(), 0);

        // both executed and cache-served is rejected
        let mut t = ScheduleTrace::default();
        t.record_cache_hit(TaskId(0));
        t.push(ev(0, 0, 0, 10));
        t.push(ev(1, 0, 10, 20));
        assert!(t.validate(&p).is_err());
    }

    #[test]
    fn ledger_resumed_tasks_validate() {
        let p = chain2();
        let mut t = ScheduleTrace::default();
        t.record_resumed(TaskId(0));
        t.push(ev(1, 0, 5, 10));
        t.validate(&p).unwrap();

        // resumed and executed is rejected
        let mut t = ScheduleTrace::default();
        t.record_resumed(TaskId(0));
        t.push(ev(0, 0, 0, 10));
        t.push(ev(1, 0, 10, 20));
        assert!(t.validate(&p).is_err());

        // resumed and cache-served is rejected
        let mut t = ScheduleTrace::default();
        t.record_resumed(TaskId(0));
        t.record_cache_hit(TaskId(0));
        t.push(ev(1, 0, 10, 20));
        assert!(t.validate(&p).is_err());
    }

    #[test]
    fn attempt_won_marks_the_latest_matching_attempt() {
        let mut t = ScheduleTrace::default();
        t.record_attempt(TaskId(3), WorkerId(0), false, 10);
        t.record_attempt(TaskId(3), WorkerId(1), true, 20);
        t.record_attempt(TaskId(3), WorkerId(0), false, 30);
        t.mark_attempt_won(TaskId(3), WorkerId(0));
        assert!(!t.attempts[0].won, "earlier attempt on w0 stays lost");
        assert!(!t.attempts[1].won);
        assert!(t.attempts[2].won, "latest w0 attempt is the committed one");
        assert!(t.attempts[1].speculative);
    }

    #[test]
    fn utilization_of_perfect_parallel_run() {
        let mut t = ScheduleTrace::default();
        t.push(ev(0, 0, 0, 100));
        t.push(ev(1, 1, 0, 100));
        assert!((t.utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gantt_renders_rows() {
        let mut t = ScheduleTrace::default();
        t.push(ev(0, 0, 0, 50));
        t.push(ev(1, 1, 50, 100));
        let g = t.gantt(20);
        assert!(g.starts_with("w0 |"));
        assert!(g.contains("\nw1 |"));
        assert!(g.contains('0') && g.contains('1'));
    }
}
