//! Integration test: the paper's Figure 1, node- and edge-exact, from
//! source text through the full frontend.

use parhask::depgraph::{analyze, build_depgraph, dot, EdgeKind};
use parhask::frontend::parse_program;
use parhask::types::check_program;

const PAPER_PROGRAM: &str = r#"
data Summary = Opaque

clean_files :: IO Summary
clean_files = primitive

complex_evaluation :: Summary -> Int
complex_evaluation x = primitive

semantic_analysis :: IO Int
semantic_analysis = primitive

primitive :: Int
primitive = 0

main :: IO ()
main = do
  x <- clean_files
  let y = complex_evaluation x
  z <- semantic_analysis
  print (y, z)
"#;

#[test]
fn figure1_graph_is_exact() {
    let ast = parse_program(PAPER_PROGRAM).unwrap();
    let checked = check_program(&ast, "main").unwrap();
    let g = build_depgraph(&checked).unwrap();

    // Exactly the 4 call nodes of Figure 1.
    assert_eq!(g.len(), 4);
    let cf = g.find_by_func("clean_files").unwrap();
    let ce = g.find_by_func("complex_evaluation").unwrap();
    let sa = g.find_by_func("semantic_analysis").unwrap();
    let pr = g.find_by_func("print").unwrap();

    // Node classification.
    assert!(g.node(cf).io && g.node(sa).io && g.node(pr).io);
    assert!(!g.node(ce).io);
    assert_eq!(g.node(cf).binds.as_deref(), Some("x"));
    assert_eq!(g.node(ce).binds.as_deref(), Some("y"));
    assert_eq!(g.node(sa).binds.as_deref(), Some("z"));

    // Value edges, with the variables they carry.
    let val_edges: Vec<(_, _, String)> = g
        .edges()
        .iter()
        .filter_map(|e| match &e.kind {
            EdgeKind::Value(v) => Some((e.src, e.dst, v.clone())),
            EdgeKind::World => None,
        })
        .collect();
    assert!(val_edges.contains(&(cf, ce, "x".to_string())));
    assert!(val_edges.contains(&(ce, pr, "y".to_string())));
    assert!(val_edges.contains(&(sa, pr, "z".to_string())));
    assert_eq!(val_edges.len(), 3);

    // RealWorld chain.
    let world: Vec<(_, _)> = g
        .edges()
        .iter()
        .filter(|e| e.kind == EdgeKind::World)
        .map(|e| (e.src, e.dst))
        .collect();
    assert_eq!(world, vec![(cf, sa), (sa, pr)]);

    // The parallelism the paper highlights: width 2 after clean_files.
    let stats = analyze::analyze(&g, |_| 1.0);
    assert_eq!(stats.max_width, 2);
    assert_eq!(stats.depth, 3);
    assert_eq!(stats.io_nodes, 3);
}

#[test]
fn figure1_dot_renders_all_elements() {
    let ast = parse_program(PAPER_PROGRAM).unwrap();
    let checked = check_program(&ast, "main").unwrap();
    let g = build_depgraph(&checked).unwrap();
    let d = dot::to_dot(&g, "Figure 1");
    for needle in [
        "clean_files",
        "complex_evaluation",
        "semantic_analysis",
        "print",
        "doubleoctagon",           // IO node shape
        "shape=box",               // pure node shape
        "RealWorld",               // token edges + source
        "label=\"x\"",
        "label=\"y\"",
        "label=\"z\"",
        "world0",
    ] {
        assert!(d.contains(needle), "DOT missing {needle:?}:\n{d}");
    }
}

#[test]
fn entry_point_other_than_main_reproduces_subgraph() {
    // the paper's future-work note: parallelize an arbitrary function
    let src = format!(
        "{PAPER_PROGRAM}\npipeline :: IO ()\npipeline = do\n  a <- clean_files\n  let b = complex_evaluation a\n  print b\n"
    );
    let ast = parse_program(&src).unwrap();
    let checked = check_program(&ast, "pipeline").unwrap();
    let g = build_depgraph(&checked).unwrap();
    assert_eq!(g.len(), 3);
}
