//! Purity-aware, content-addressed result cache.
//!
//! The paper's central guarantee — pure tasks may run anywhere, in any
//! dependency-consistent order, and may be *re-executed* — also makes
//! their results *memoizable*: a pure task applied to the same input
//! values is the same value, wherever and whenever it ran. This module
//! exploits that for serving repeated traffic:
//!
//! * [`key`] — stable 128-bit task keys: hash of (op wire encoding,
//!   canonicalized input-value encodings). Content-addressed, so hits
//!   transfer across runs *and across different programs* that contain
//!   the same sub-computation;
//! * [`lru`] — sharded in-memory LRU store (byte + entry capped);
//! * [`stats`] — hit/miss/eviction counters surfaced through `metrics`.
//!
//! All four engines consult one [`ResultCache`] through the same two
//! calls: `lookup(spec, args)` before executing and `insert(spec, args,
//! outputs)` after. Purity gating is absolute: a task whose op is not
//! certifiably pure ([`crate::ir::task::OpKind::is_pure`], rooted in the
//! `types::purity` signature analysis) is never looked up or stored, and
//! individual ops can additionally be denied by label through
//! [`CacheConfig::deny`] (e.g. when an artifact wraps a function whose
//! type signature says `IO`).

pub mod key;
pub mod lru;
pub mod stats;

use std::collections::BTreeSet;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::ir::task::{TaskSpec, Value};
use crate::types::PurityTable;

pub use key::{task_key, task_key_in, TaskKey};
pub use stats::{CacheCounters, CacheStats};

use lru::ShardedLru;

/// Result-cache configuration (part of [`crate::config::RunConfig`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheConfig {
    /// Master switch. Off by default: `--cache off` (or simply not passing
    /// `--cache on`) preserves the exact pre-cache execution paths.
    pub enabled: bool,
    /// Total resident-value budget in bytes.
    pub capacity_bytes: usize,
    /// Total resident-entry budget.
    pub max_entries: usize,
    /// Lock shards (rounded up to ≥ 1).
    pub shards: usize,
    /// Op labels (see `OpKind::label`) that must never be cached even
    /// though their op kind looks pure — the per-op opt-out for anything
    /// `types::purity` cannot certify.
    pub deny: BTreeSet<String>,
    /// Key namespace. Partitions the store by anything outside task
    /// content that changes result bits — the CLI sets it to the executor
    /// backend ("host" vs "pjrt") so a cache shared across runs can never
    /// serve one backend's floats to the other.
    pub namespace: String,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            enabled: false,
            capacity_bytes: 256 << 20, // 256 MiB
            max_entries: 1 << 16,
            shards: 16,
            deny: BTreeSet::new(),
            namespace: String::new(),
        }
    }
}

impl CacheConfig {
    /// Deny a single op label.
    pub fn deny_op(&mut self, label: impl Into<String>) {
        self.deny.insert(label.into());
    }

    /// Deny every function the purity analysis classifies as IO. Lowering
    /// already turns those into impure `IoAction` ops, so this is defense
    /// in depth for environments that bind IO-typed names to artifacts.
    pub fn deny_io_from(&mut self, purity: &PurityTable) {
        for name in purity.io_names() {
            self.deny.insert(name.to_string());
        }
    }
}

/// The shared result cache. Cheap to clone via `Arc`; hold one across runs
/// to serve repeated traffic warm.
pub struct ResultCache {
    cfg: CacheConfig,
    store: ShardedLru,
    counters: CacheCounters,
}

impl ResultCache {
    pub fn new(cfg: CacheConfig) -> Arc<ResultCache> {
        let store = ShardedLru::new(cfg.shards, cfg.capacity_bytes, cfg.max_entries);
        Arc::new(ResultCache {
            cfg,
            store,
            counters: CacheCounters::default(),
        })
    }

    /// Convenience: an enabled cache with default sizing (tests, examples).
    pub fn new_enabled() -> Arc<ResultCache> {
        ResultCache::new(CacheConfig {
            enabled: true,
            ..CacheConfig::default()
        })
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// May this task's result ever enter the cache? Purity is the paper's
    /// criterion; the deny list is the operator's.
    pub fn cacheable(&self, spec: &TaskSpec) -> bool {
        self.cfg.enabled && spec.is_pure() && !self.denied(&spec.op)
    }

    /// Label-based denial, extended so a denied whole op also denies its
    /// partition-pass shards: a matgen shard's label embeds its row range,
    /// so the operator's `--cache_deny host_matgen_N` must keep applying
    /// when `--partitions` is on. (Synthetic shards change duration and
    /// hence label — deny the shard labels directly if that ever matters.)
    fn denied(&self, op: &crate::ir::task::OpKind) -> bool {
        if self.cfg.deny.contains(&op.label()) {
            return true;
        }
        if let crate::ir::task::OpKind::HostMatGenShard { n, .. } = op {
            return self
                .cfg
                .deny
                .contains(&crate::ir::task::OpKind::HostMatGen { n: *n }.label());
        }
        false
    }

    /// The task's content key within this cache's namespace. The cluster
    /// leader computes it once for lookup + in-flight dedup.
    pub fn key_for(&self, spec: &TaskSpec, args: &[Value]) -> TaskKey {
        key::task_key_in(&self.cfg.namespace, &spec.op, args)
    }

    /// Look up the task's result by content. `None` means "execute it"
    /// (uncacheable or miss — the counters distinguish the two).
    pub fn lookup(&self, spec: &TaskSpec, args: &[Value]) -> Option<Vec<Value>> {
        if !self.cacheable(spec) {
            self.counters.uncacheable.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let key = self.key_for(spec, args);
        match self.store.get(&key) {
            Some(outputs) => {
                self.counters.hits.fetch_add(1, Ordering::Relaxed);
                Some(outputs)
            }
            None => {
                self.counters.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store a computed result (no-op for uncacheable tasks).
    pub fn insert(&self, spec: &TaskSpec, args: &[Value], outputs: &[Value]) {
        if !self.cacheable(spec) {
            return;
        }
        let key = self.key_for(spec, args);
        self.insert_by_key(key, outputs);
    }

    /// Count a hit that bypassed the store: the cluster leader served a
    /// task from an identical completed in-flight computation (dedup), so
    /// trace hit counts and store counters stay in agreement.
    pub fn note_dedup_hit(&self) {
        self.counters.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Key-level variants for callers that computed the key via
    /// [`Self::key_for`] already (the cluster leader).
    pub fn lookup_key(&self, key: &TaskKey) -> Option<Vec<Value>> {
        match self.store.get(key) {
            Some(outputs) => {
                self.counters.hits.fetch_add(1, Ordering::Relaxed);
                Some(outputs)
            }
            None => {
                self.counters.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert_by_key(&self, key: TaskKey, outputs: &[Value]) {
        let out = self.store.insert(key, outputs.to_vec());
        if out.inserted {
            self.counters.insertions.fetch_add(1, Ordering::Relaxed);
        }
        if out.rejected_oversize {
            self.counters
                .rejected_oversize
                .fetch_add(1, Ordering::Relaxed);
        }
        if out.evicted_entries > 0 {
            self.counters
                .evictions
                .fetch_add(out.evicted_entries, Ordering::Relaxed);
            self.counters
                .evicted_bytes
                .fetch_add(out.evicted_bytes, Ordering::Relaxed);
        }
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    pub fn clear(&self) {
        self.store.clear();
    }

    /// Counter snapshot including resident sizes.
    pub fn stats(&self) -> CacheStats {
        let mut s = self.counters.snapshot();
        s.resident_entries = self.store.len() as u64;
        s.resident_bytes = self.store.bytes() as u64;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::task::{CostEst, OpKind, TaskId};

    fn spec(op: OpKind) -> TaskSpec {
        TaskSpec {
            id: TaskId(0),
            op,
            args: vec![],
            n_outputs: 1,
            est: CostEst::ZERO,
            label: "t".into(),
            shard: None,
        }
    }

    #[test]
    fn disabled_cache_never_hits_or_stores() {
        let c = ResultCache::new(CacheConfig::default()); // enabled: false
        let s = spec(OpKind::HostMatSum);
        let args = [Value::scalar_f32(1.0)];
        c.insert(&s, &args, &[Value::scalar_f32(9.0)]);
        assert!(c.lookup(&s, &args).is_none());
        assert_eq!(c.len(), 0);
        assert_eq!(c.stats().hits + c.stats().misses, 0);
        assert!(c.stats().uncacheable > 0);
    }

    #[test]
    fn pure_task_roundtrips() {
        let c = ResultCache::new_enabled();
        let s = spec(OpKind::HostMatSum);
        let args = [Value::scalar_f32(1.0)];
        assert!(c.lookup(&s, &args).is_none()); // cold miss
        c.insert(&s, &args, &[Value::scalar_f32(9.0)]);
        let out = c.lookup(&s, &args).unwrap();
        assert_eq!(out[0].as_tensor().unwrap().scalar().unwrap(), 9.0);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.insertions), (1, 1, 1));
    }

    #[test]
    fn impure_task_never_cached() {
        let c = ResultCache::new_enabled();
        let s = spec(OpKind::IoAction {
            label: "print".into(),
            compute_us: 0,
        });
        let args = [Value::Token];
        c.insert(&s, &args, &[Value::Unit, Value::Token]);
        assert!(c.lookup(&s, &args).is_none());
        assert_eq!(c.len(), 0);
        assert_eq!(c.stats().hits + c.stats().misses, 0, "never counted as cacheable");
    }

    #[test]
    fn denying_a_matgen_denies_its_shards() {
        let mut cfg = CacheConfig {
            enabled: true,
            ..CacheConfig::default()
        };
        cfg.deny_op("host_matgen_64");
        let c = ResultCache::new(cfg);
        let shard = spec(OpKind::HostMatGenShard { n: 64, row0: 16, rows: 16 });
        c.insert(&shard, &[], &[Value::Unit]);
        assert!(c.lookup(&shard, &[]).is_none());
        assert_eq!(c.len(), 0, "a denied whole op denies its shards too");
        // a different size's shards stay cacheable
        let other = spec(OpKind::HostMatGenShard { n: 32, row0: 0, rows: 16 });
        assert!(c.cacheable(&other));
    }

    #[test]
    fn deny_list_blocks_pure_looking_ops() {
        let mut cfg = CacheConfig {
            enabled: true,
            ..CacheConfig::default()
        };
        cfg.deny_op("shady_artifact");
        let c = ResultCache::new(cfg);
        let s = spec(OpKind::Artifact {
            name: "shady_artifact".into(),
        });
        c.insert(&s, &[], &[Value::Unit]);
        assert!(c.lookup(&s, &[]).is_none());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn deny_io_from_purity_table() {
        let p = crate::frontend::parse_program(
            "fetch :: IO Int\nfetch = prim\n\nsquare :: Int -> Int\nsquare x = prim x\n",
        )
        .unwrap();
        let t = PurityTable::from_program(&p).unwrap();
        let mut cfg = CacheConfig::default();
        cfg.deny_io_from(&t);
        assert!(cfg.deny.contains("fetch"));
        assert!(cfg.deny.contains("print")); // builtin effect
        assert!(!cfg.deny.contains("square"));
    }

    #[test]
    fn oversize_rejections_are_counted_and_midsize_admitted() {
        let c = ResultCache::new(CacheConfig {
            enabled: true,
            capacity_bytes: 1000,
            shards: 4,
            ..CacheConfig::default()
        });
        let s = spec(OpKind::HostMatSum);
        // 256 B > shard budget (250 B) but well under total/2: must land
        // (this was silently refused when insert compared per-shard)
        let mid = [Value::scalar_f32(1.0)];
        c.insert(&s, &mid, &[Value::tensor(crate::tensor::Tensor::zeros(vec![64]))]);
        assert!(c.lookup(&s, &mid).is_some(), "mid-size entry must be cached");
        assert_eq!(c.stats().rejected_oversize, 0);
        // 2048 B > total/2: refused, and the refusal is observable
        let big = [Value::scalar_f32(2.0)];
        c.insert(&s, &big, &[Value::tensor(crate::tensor::Tensor::zeros(vec![512]))]);
        assert!(c.lookup(&s, &big).is_none());
        let st = c.stats();
        assert_eq!(st.rejected_oversize, 1);
        assert_eq!(st.insertions, 1);
    }

    #[test]
    fn different_args_different_entries() {
        let c = ResultCache::new_enabled();
        let s = spec(OpKind::HostMatSum);
        c.insert(&s, &[Value::scalar_f32(1.0)], &[Value::scalar_f32(10.0)]);
        c.insert(&s, &[Value::scalar_f32(2.0)], &[Value::scalar_f32(20.0)]);
        assert_eq!(c.len(), 2);
        let a = c.lookup(&s, &[Value::scalar_f32(1.0)]).unwrap();
        let b = c.lookup(&s, &[Value::scalar_f32(2.0)]).unwrap();
        assert_eq!(a[0].as_tensor().unwrap().scalar().unwrap(), 10.0);
        assert_eq!(b[0].as_tensor().unwrap().scalar().unwrap(), 20.0);
    }
}
