//! Discrete-event cluster simulator.
//!
//! Why it exists: the paper's Figure 2 sweeps *worker count*, but this
//! testbed has one CPU core (and the paper itself "simulated" its workers
//! with Cloud Haskell on one box). The simulator executes the same greedy
//! scheduler state machine as the real leader, in virtual time, with
//! per-op costs **calibrated from real PJRT runs** (`parhask calibrate`)
//! and an explicit network model — so scaling *shape* (who wins, where
//! the crossover falls) is faithful even though wall-clock is not
//! measurable here. See DESIGN.md §7 (substitution log).

pub mod calibrate;
pub mod costmodel;
pub mod sim;

pub use costmodel::CostModel;
pub use sim::{simulate, simulate_with_faults, SimConfig, SimResult};
