//! Minimal JSON parser + emitter.
//!
//! The offline vendor set has no `serde_json`, and this repo needs JSON in
//! three places: the AOT `manifest.json` contract with Layer-2, the
//! calibrated cost-model file, and machine-readable bench reports. The
//! subset implemented is full JSON (RFC 8259) minus `\u` surrogate pairs
//! being validated pedantically — they decode best-effort.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic — bench reports diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // -- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- parsing ----------------------------------------------------------

    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy raw bytes through
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = (start + len).min(self.b.len());
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Emission
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().get("b"), Some(&Json::Bool(false)));
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"nested":{"t":true,"n":null},"neg":-7}"#;
        let j = Json::parse(src).unwrap();
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let j = Json::Str("héllo\t\"wörld\" \u{1}".into());
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).unwrap();
            assert!(j.get("artifacts").unwrap().as_arr().unwrap().len() >= 16);
        }
    }
}
